"""Unit tests for the multiproc transport subsystem (jax-light: no
emulated-device subprocesses; real processes only where the launcher is
the thing under test)."""

from __future__ import annotations

import os
import signal
import socket
import threading
import time

import numpy as np
import pytest

from repro.transport import base
from repro.transport.sock import SockWire


# ---------------------------------------------------------------------------
# frame protocol
# ---------------------------------------------------------------------------

def _wire_pair():
    a, b = socket.socketpair()
    return SockWire(a), SockWire(b)


@pytest.mark.parametrize("dtype", ["float32", "float64", "int32", "int64",
                                   "complex64", "bfloat16"])
def test_frame_array_roundtrip(dtype):
    if dtype == "bfloat16":
        import ml_dtypes
        np_dtype = np.dtype(ml_dtypes.bfloat16)
    else:
        np_dtype = np.dtype(dtype)
    arr = np.arange(12).reshape(3, 4).astype(np_dtype)
    w0, w1 = _wire_pair()
    meta, data = base.encode_array(arr)
    base.send_frame(w0, base.KIND_ARRAY, tag=7, epoch=3, meta=meta, data=data)
    kind, tag, epoch, meta2, data2 = base.recv_frame(
        w1, time.monotonic() + 5)
    assert (kind, tag, epoch) == (base.KIND_ARRAY, 7, 3)
    out = base.decode_array(meta2, data2)
    assert out.dtype == arr.dtype and out.shape == arr.shape
    np.testing.assert_array_equal(out, arr)
    w0.close(), w1.close()


def test_frame_array_noncontiguous():
    arr = np.arange(24.0).reshape(4, 6)[::2, ::3]  # strided view
    meta, data = base.encode_array(arr)
    np.testing.assert_array_equal(base.decode_array(meta, data), arr)


def test_frame_array_zero_dim():
    # regression: ascontiguousarray promotes 0-d to (1,); a scalar
    # allreduce payload must come off the wire still 0-d
    arr = np.asarray(np.float32(2.5))
    out = base.decode_array(*base.encode_array(arr))
    assert out.shape == () and out == np.float32(2.5)


def test_frame_obj_and_ctrl_roundtrip():
    w0, w1 = _wire_pair()
    meta, data = base.encode_obj({"err": None, "n": [1, 2]})
    base.send_frame(w0, base.KIND_OBJ, tag=-12, epoch=0, meta=meta, data=data)
    base.send_frame(w0, base.KIND_CTRL, tag=-101, epoch=0)
    kind, _, _, _, data2 = base.recv_frame(w1, time.monotonic() + 5)
    assert kind == base.KIND_OBJ
    assert base.decode_obj(data2) == {"err": None, "n": [1, 2]}
    kind, tag, _, meta3, data3 = base.recv_frame(w1, time.monotonic() + 5)
    assert (kind, tag, meta3, data3) == (base.KIND_CTRL, -101, b"", b"")
    w0.close(), w1.close()


def test_frame_recv_timeout_and_eof():
    w0, w1 = _wire_pair()
    with pytest.raises(TimeoutError):
        base.recv_frame(w1, time.monotonic() + 0.3)
    w0.close()
    with pytest.raises(EOFError):
        base.recv_frame(w1, time.monotonic() + 5)
    w1.close()


# ---------------------------------------------------------------------------
# shm ring
# ---------------------------------------------------------------------------

def test_shm_ring_wraparound():
    """Stream several ring capacities through one SPSC ring: exercises the
    wrap-around copy split and the monotonic head/tail counters."""
    from repro.transport import shm as shm_mod

    seg = shm_mod._attach(f"jmpi_test_{os.getpid()}", create=True,
                          deadline=time.monotonic() + 5)
    writer = shm_mod._Ring(seg, writer=True, owner=False)
    reader = shm_mod._Ring(seg, writer=False, owner=False)
    total = 3 * shm_mod.RING_SIZE + 12345
    rng = np.random.default_rng(0)
    payload = rng.integers(0, 256, size=total, dtype=np.uint8).tobytes()

    def produce():
        deadline = time.monotonic() + 30
        for ofs in range(0, total, 70_001):  # odd chunking vs. ring size
            writer.write(payload[ofs:ofs + 70_001], deadline)

    t = threading.Thread(target=produce, daemon=True)
    t.start()
    got = reader.read(total, time.monotonic() + 30)
    t.join(timeout=10)
    assert got == payload
    seg.close()
    seg.unlink()


# ---------------------------------------------------------------------------
# endpoint: tag matching, epochs, barrier — two endpoints in one process
# ---------------------------------------------------------------------------

class _PairTransport(base.Transport):
    kind = "sock"

    def __init__(self, wires):
        self._wires = wires

    def wire(self, peer):
        return self._wires[peer]

    def close(self):
        for w in self._wires.values():
            w.close()


@pytest.fixture()
def endpoints():
    from repro.transport.endpoint import Endpoint
    s0, s1 = socket.socketpair()
    ep0 = Endpoint(_PairTransport({1: SockWire(s0)}), 0, 2, timeout=1.0)
    ep1 = Endpoint(_PairTransport({0: SockWire(s1)}), 1, 2, timeout=1.0)
    yield ep0, ep1
    ep0.close()
    ep1.close()


def test_endpoint_tag_matching_out_of_order(endpoints):
    ep0, ep1 = endpoints
    a, b = np.arange(3.0), np.arange(4) + 10
    ep0.send_array(1, a, tag=5)
    ep0.send_array(1, b, tag=3)
    # tag 3 arrived second but is claimable first; tag 5 stays pending.
    np.testing.assert_array_equal(ep1.recv_array(0, 3), b)
    np.testing.assert_array_equal(ep1.recv_array(0, 5), a)


def test_endpoint_obj_and_barrier(endpoints):
    ep0, ep1 = endpoints
    ep0.send_obj(1, ("payload", 42))
    assert ep1.recv_obj(0) == ("payload", 42)
    done = []
    t = threading.Thread(target=lambda: (ep1.barrier(), done.append(1)),
                         daemon=True)
    t.start()
    ep0.barrier()
    t.join(timeout=5)
    assert done == [1]


def test_endpoint_epoch_discards_stale_frames(endpoints):
    ep0, ep1 = endpoints
    ep0.send_array(1, np.arange(3.0), tag=5)   # epoch 0: will go stale
    ep1.bump_epoch()                           # ep1 now only accepts epoch 1
    with pytest.raises(TimeoutError, match="no frame"):
        ep1.recv_array(0, 5)
    ep0.bump_epoch()
    fresh = np.arange(4.0) + 1
    ep0.send_array(1, fresh, tag=5)
    np.testing.assert_array_equal(ep1.recv_array(0, 5), fresh)


def test_endpoint_future_epoch_stays_pending(endpoints):
    ep0, ep1 = endpoints
    ep0.bump_epoch()                           # ep0 runs ahead
    future = np.arange(5.0)
    ep0.send_array(1, future, tag=9)
    with pytest.raises(TimeoutError):          # not claimable at epoch 0 ...
        ep1.recv_array(0, 9)
    ep1.bump_epoch()                           # ... but kept, not dropped
    np.testing.assert_array_equal(ep1.recv_array(0, 9), future)


def test_endpoint_peer_close_is_an_error(endpoints):
    ep0, ep1 = endpoints
    ep0.close()
    with pytest.raises(RuntimeError, match="closed its wire"):
        ep1.recv_array(0, 5)


# ---------------------------------------------------------------------------
# launcher hardening: crash/timeout containment, zero orphans
# ---------------------------------------------------------------------------

def _assert_all_dead(job):
    for p in job.procs:
        assert p.poll() is not None, f"worker pid {p.pid} still running"
    for pid in job.pids():
        # reparented orphans would still answer signal 0
        try:
            os.kill(pid, 0)
        except ProcessLookupError:
            continue
        # pid exists: must be our own zombie already reaped by Popen.wait
        assert False, f"orphan worker pid {pid} survived teardown"


def test_launcher_kill_mid_collective_no_orphans():
    """SIGKILL one worker of a live shm job mid-barrier: wait() must raise
    promptly, every other worker must be reaped, and every shared-memory
    segment must be unlinked."""
    from repro.transport import launch, WorkerFailure

    job = launch(2, "repro.transport.testing:_spin_entry", transport="shm",
                 args={"seconds": 120}, timeout=60)
    try:
        time.sleep(2.0)  # let the mesh come up and the barrier loop spin
        os.kill(job.pids()[1], signal.SIGKILL)
        t0 = time.monotonic()
        with pytest.raises(WorkerFailure, match="rank 1"):
            job.wait()
        assert time.monotonic() - t0 < 30, "dead worker detected too slowly"
        _assert_all_dead(job)
        from multiprocessing import shared_memory
        from repro.transport.shm import segment_name
        for i, j in ((0, 1), (1, 0)):
            with pytest.raises(FileNotFoundError):
                shared_memory.SharedMemory(
                    name=segment_name(job.session, i, j))
    finally:
        job.close()


def test_launcher_job_timeout_reaps_workers():
    from repro.transport import launch

    job = launch(2, "repro.transport.testing:_spin_entry",
                 args={"seconds": 120}, timeout=6)
    try:
        with pytest.raises(TimeoutError, match="exceeded 6s"):
            job.wait()
        _assert_all_dead(job)
    finally:
        job.close()


def test_launcher_worker_exception_carries_transcript():
    from repro.transport import launch, WorkerFailure

    job = launch(2, "repro.transport.testing:_case_entry",
                 args={"module": "tests.no_such_module"}, timeout=60)
    try:
        with pytest.raises(WorkerFailure, match="no_such_module"):
            job.wait()
        _assert_all_dead(job)
    finally:
        job.close()


def test_launcher_rejects_bad_arguments():
    from repro.transport import launch

    with pytest.raises(ValueError, match="transport"):
        launch(2, "mod:fn", transport="carrier-pigeon")
    with pytest.raises(ValueError, match="module:function"):
        launch(2, "not-an-entry")


# ---------------------------------------------------------------------------
# satellite: plan cache keys carry backend/transport identity
# ---------------------------------------------------------------------------

def test_plan_cache_backend_identity():
    import jax.numpy as jnp

    from repro.core import plans
    from repro.core.comm import Communicator
    from repro.transport.endpoint import MultiprocComm

    emu = Communicator(("ranks",), 0)
    shm = MultiprocComm(("proc",), 0, rank_id=0, nprocs=2,
                        transport_kind="shm")
    sock = MultiprocComm(("proc",), 0, rank_id=0, nprocs=2,
                         transport_kind="sock")
    keys = {plans._backend_key(c) for c in (emu, shm, sock)}
    assert len(keys) == 3, "backend/transport identity must split cache keys"

    plans.plan_cache_clear()
    x = jnp.zeros((4,), jnp.float32)
    p_shm = plans.allreduce_init(x, comm=shm)
    p_sock = plans.allreduce_init(x, comm=sock)
    assert p_shm is not p_sock, "shm plan served to a sock communicator"
    assert plans.allreduce_init(x, comm=sock) is p_sock
    stats = plans.plan_cache_stats()
    assert stats["by_backend"]["multiproc"]["misses"] >= 2
    assert stats["by_backend"]["multiproc"]["hits"] >= 1
