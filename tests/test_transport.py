"""Unit tests for the multiproc transport subsystem (jax-light: no
emulated-device subprocesses; real processes only where the launcher is
the thing under test)."""

from __future__ import annotations

import os
import signal
import socket
import threading
import time

import numpy as np
import pytest

from repro.transport import base
from repro.transport.sock import SockWire


# ---------------------------------------------------------------------------
# frame protocol
# ---------------------------------------------------------------------------

def _wire_pair():
    a, b = socket.socketpair()
    return SockWire(a), SockWire(b)


@pytest.mark.parametrize("dtype", ["float32", "float64", "int32", "int64",
                                   "complex64", "bfloat16"])
def test_frame_array_roundtrip(dtype):
    if dtype == "bfloat16":
        import ml_dtypes
        np_dtype = np.dtype(ml_dtypes.bfloat16)
    else:
        np_dtype = np.dtype(dtype)
    arr = np.arange(12).reshape(3, 4).astype(np_dtype)
    w0, w1 = _wire_pair()
    meta, data = base.encode_array(arr)
    base.send_frame(w0, base.KIND_ARRAY, tag=7, epoch=3, meta=meta, data=data)
    kind, tag, epoch, meta2, data2 = base.recv_frame(
        w1, time.monotonic() + 5)
    assert (kind, tag, epoch) == (base.KIND_ARRAY, 7, 3)
    out = base.decode_array(meta2, data2)
    assert out.dtype == arr.dtype and out.shape == arr.shape
    np.testing.assert_array_equal(out, arr)
    w0.close(), w1.close()


def test_frame_array_noncontiguous():
    arr = np.arange(24.0).reshape(4, 6)[::2, ::3]  # strided view
    meta, data = base.encode_array(arr)
    np.testing.assert_array_equal(base.decode_array(meta, data), arr)


def test_frame_array_zero_dim():
    # regression: ascontiguousarray promotes 0-d to (1,); a scalar
    # allreduce payload must come off the wire still 0-d
    arr = np.asarray(np.float32(2.5))
    out = base.decode_array(*base.encode_array(arr))
    assert out.shape == () and out == np.float32(2.5)


def test_frame_obj_and_ctrl_roundtrip():
    w0, w1 = _wire_pair()
    meta, data = base.encode_obj({"err": None, "n": [1, 2]})
    base.send_frame(w0, base.KIND_OBJ, tag=-12, epoch=0, meta=meta, data=data)
    base.send_frame(w0, base.KIND_CTRL, tag=-101, epoch=0)
    kind, _, _, _, data2 = base.recv_frame(w1, time.monotonic() + 5)
    assert kind == base.KIND_OBJ
    assert base.decode_obj(data2) == {"err": None, "n": [1, 2]}
    kind, tag, _, meta3, data3 = base.recv_frame(w1, time.monotonic() + 5)
    assert (kind, tag, meta3, data3) == (base.KIND_CTRL, -101, b"", b"")
    w0.close(), w1.close()


def test_frame_recv_timeout_and_eof():
    w0, w1 = _wire_pair()
    with pytest.raises(TimeoutError):
        base.recv_frame(w1, time.monotonic() + 0.3)
    w0.close()
    with pytest.raises(EOFError):
        base.recv_frame(w1, time.monotonic() + 5)
    w1.close()


# ---------------------------------------------------------------------------
# shm ring
# ---------------------------------------------------------------------------

def test_shm_ring_wraparound():
    """Stream several ring capacities through one SPSC ring: exercises the
    wrap-around copy split and the monotonic head/tail counters."""
    from repro.transport import shm as shm_mod

    seg = shm_mod._attach(f"jmpi_test_{os.getpid()}", create=True,
                          deadline=time.monotonic() + 5)
    writer = shm_mod._Ring(seg, writer=True, owner=False)
    reader = shm_mod._Ring(seg, writer=False, owner=False)
    total = 3 * shm_mod.RING_SIZE + 12345
    rng = np.random.default_rng(0)
    payload = rng.integers(0, 256, size=total, dtype=np.uint8).tobytes()

    def produce():
        deadline = time.monotonic() + 30
        for ofs in range(0, total, 70_001):  # odd chunking vs. ring size
            writer.write(payload[ofs:ofs + 70_001], deadline)

    t = threading.Thread(target=produce, daemon=True)
    t.start()
    got = reader.read(total, time.monotonic() + 30)
    t.join(timeout=10)
    assert got == payload
    seg.close()
    seg.unlink()


def test_backoff_spin_then_sleep_phases():
    """Backoff reports False through the spin+yield phases, True once it
    sleeps; reset() restarts the spin phase."""
    bo = base.Backoff(spin=5, min_sleep=1e-6, max_sleep=1e-5)
    phases = [bo.pause() for _ in range(5 + 4)]  # spin + the sleep(0) yields
    assert not any(phases), "spin/yield pauses must report False"
    assert bo.pause() is True, "first real sleep must report True"
    bo.reset()
    assert bo.pause() is False, "reset must restart the spin phase"


def test_decode_array_owned_skips_copy():
    arr = np.arange(12, dtype=np.float32)
    meta, data = base.encode_array(arr)
    data = bytearray(data)  # what an owning wire recv actually hands over
    view = base.decode_array(meta, data, owned=True)
    copy = base.decode_array(meta, data, owned=False)
    np.testing.assert_array_equal(view, arr)
    np.testing.assert_array_equal(copy, arr)
    assert np.shares_memory(view, np.frombuffer(data, np.uint8)), \
        "owned decode must alias the recv buffer (zero copy)"
    assert not np.shares_memory(copy, np.frombuffer(data, np.uint8)), \
        "borrowed decode must defensively copy"


def _rtt_echo(name_a, name_b, n):
    # Child side of the ring round-trip test below (module-level so the
    # spawn start method can pickle it; spawn avoids forking a process
    # that already holds JAX's internal threads).
    from repro.transport import shm as shm_mod
    d = time.monotonic() + 60
    a = shm_mod._attach(name_a, create=False, deadline=d)
    b = shm_mod._attach(name_b, create=False, deadline=d)
    ra = shm_mod._Ring(a, writer=False, owner=False)
    wb = shm_mod._Ring(b, writer=True, owner=False)
    for _ in range(n):
        wb.write(ra.read(1, d), d)
    a.close()
    b.close()


def test_shm_ring_roundtrip_latency_floor():
    """Adaptive spin-then-backoff ring waits: the cross-process 1-byte
    round trip must sit far below the old fixed 200µs-poll floor (two
    polls per RTT ≈ 400µs+); the spin path lands in the ~10µs range, so
    a 200µs median bound has wide margin yet catches a poll-sleep
    regression outright."""
    import multiprocessing as mp

    from repro.transport import shm as shm_mod

    d = time.monotonic() + 20
    na, nb = f"jmpi_rtt_a_{os.getpid()}", f"jmpi_rtt_b_{os.getpid()}"
    seg_a = shm_mod._attach(na, create=True, deadline=d)
    seg_b = shm_mod._attach(nb, create=True, deadline=d)
    try:
        wa = shm_mod._Ring(seg_a, writer=True, owner=False)
        rb = shm_mod._Ring(seg_b, writer=False, owner=False)
        n = 300
        proc = mp.get_context("spawn").Process(
            target=_rtt_echo, args=(na, nb, n), daemon=True)
        proc.start()
        deadline = time.monotonic() + 60
        rtts_us = []
        for _ in range(n):
            t0 = time.perf_counter()
            wa.write(b"x", deadline)
            rb.read(1, deadline)
            rtts_us.append((time.perf_counter() - t0) * 1e6)
        proc.join(timeout=30)
        assert proc.exitcode == 0
        median = sorted(rtts_us)[n // 2]
        assert median < 200.0, (
            f"ring RTT median {median:.0f}µs — the adaptive backoff floor "
            f"should be well under the old 2×200µs poll-sleep floor")
    finally:
        for seg in (seg_a, seg_b):
            seg.close()
            seg.unlink()


# ---------------------------------------------------------------------------
# persistent channels: shm slot protocol in-process, sock negotiation
# ---------------------------------------------------------------------------

class _StubEndpoint:
    """The slice of Endpoint that ShmChannel touches."""

    def __init__(self):
        self.epoch, self.timeout, self.rank = 0, 5.0, 0
        self.chan_bytes = 0

    def _count_chan(self, payload, overhead):
        self.chan_bytes += payload + overhead


def _shm_channel_pair(key, nbytes):
    from multiprocessing import shared_memory

    from repro.transport import channel as channel_lib

    cap, _ = channel_lib.chunk_layout(nbytes)
    seg = shared_memory.SharedMemory(
        name=f"jmpi_chan_{os.getpid()}_{nbytes}", create=True,
        size=channel_lib._CTRL_BYTES + channel_lib.NSLOTS * cap)
    ep = _StubEndpoint()
    send = channel_lib.ShmChannel(ep, 1, key, seg, sender=True, owner=True)
    seg2 = shared_memory.SharedMemory(name=seg.name)
    recv = channel_lib.ShmChannel(ep, 0, key, seg2, sender=False, owner=False)
    return ep, send, recv


def test_shm_channel_single_chunk_slots():
    """Single-chunk messages move through the 2 slots with seq/ack flow
    control; the recv view is the slot itself (zero copy)."""
    key = ("sendrecv", (8,), "float32", None)
    ep, send, recv = _shm_channel_pair(key, 32)
    try:
        for i in range(5):  # > NSLOTS: exercises ack-gated slot reuse
            msg = np.full(8, float(i), np.float32)
            send.send(msg)
            got = recv.recv()
            assert np.shares_memory(got, recv._slots[i % 2]), \
                "single-chunk recv must return the slot view itself"
            np.testing.assert_array_equal(got, msg)
            recv.release()
            del got  # borrowed view: drop before the segment closes
        assert ep.chan_bytes == 5 * 32
    finally:
        send.close()
        recv.close()


def test_shm_channel_chunk_pipelined_large_message():
    """Messages above CHUNK_CAP stream through the slot window in chunks
    and reassemble exactly."""
    from repro.transport import channel as channel_lib

    n = (channel_lib.CHUNK_CAP // 4) + 12345   # > 1 chunk of float32
    key = ("sendrecv", (n,), "float32", None)
    ep, send, recv = _shm_channel_pair(key, n * 4)
    try:
        assert send._nchunks > 1
        rng = np.random.default_rng(7)
        msg = rng.standard_normal(n).astype(np.float32)
        send.send(msg)
        np.testing.assert_array_equal(recv.recv(), msg)
        recv.release()
        msg2 = msg[::-1].copy()
        send.send(msg2)
        np.testing.assert_array_equal(recv.recv(), msg2)
        recv.release()
    finally:
        send.close()
        recv.close()


def test_shm_channel_epoch_reset_reuses_segment():
    """bump_epoch-style epoch moves re-zero the stream in place: the same
    segment carries the next epoch's messages with no handshake frames."""
    key = ("sendrecv", (4,), "int64", None)
    ep, send, recv = _shm_channel_pair(key, 32)
    try:
        send.send(np.arange(4))
        np.testing.assert_array_equal(recv.recv(), np.arange(4))
        recv.release()
        ep.epoch += 1                      # collective bump (stub: shared ep)
        fresh = np.arange(4) + 100
        send.send(fresh)                   # sender republishes gen, seq=1
        assert send._count == 1, "epoch reset must restart the chunk stream"
        np.testing.assert_array_equal(recv.recv(), fresh)
        recv.release()
    finally:
        send.close()
        recv.close()


def test_endpoint_sock_channel_negotiation_and_zero_meta(endpoints):
    """open_channels over a real socketpair: batched SYN/ACK negotiation,
    both directions exchange through CHAN frames, and the steady state
    moves ZERO meta bytes and zero eager frames (the wire spy separates
    channel traffic from eager traffic)."""
    ep0, ep1 = endpoints
    key = ("sendrecv", (16,), "float32", None)
    out = {}

    def side1():
        out["tx1"], out["rx1"] = ep1.open_channels([(0, key)], [(0, key)])

    t = threading.Thread(target=side1, daemon=True)
    t.start()
    tx0, rx0 = ep0.open_channels([(1, key)], [(1, key)])
    t.join(timeout=10)
    assert "tx1" in out, "negotiation did not complete"

    ep0.reset_wire_stats()
    ep1.reset_wire_stats()
    for i in range(3):
        msg = np.full(16, float(i), np.float32)
        tx0[1].send(msg)
        got = out["rx1"][0].recv()
        np.testing.assert_array_equal(got, msg)
        out["rx1"][0].release()
        out["tx1"][0].send(msg + 1)
        got = rx0[1].recv()
        np.testing.assert_array_equal(got, msg + 1)
        rx0[1].release()
    for ep in (ep0, ep1):
        s = ep.wire_stats()
        assert s["meta_bytes"] == 0, s
        assert s["frames"] == 0, ("steady-state channel traffic must not "
                                  "touch the eager frame counters", s)
        assert s["chan_msgs"] == 3 and s["chan_bytes"] > 0, s


def test_endpoint_channel_key_mismatch_is_negotiation_error(endpoints):
    """A receiver whose frozen key disagrees with the sender's fails AT
    NEGOTIATION (init) time, not in steady state: the receiver raises the
    mismatch, the sender never gets its ACK."""
    ep0, ep1 = endpoints
    k_send = ("sendrecv", (16,), "float32", None)
    k_recv = ("sendrecv", (32,), "float32", None)   # wrong shape
    errs = {}

    def side0():
        try:
            ep0.open_channels([(1, k_send)], [])
        except Exception as e:  # noqa: BLE001 — collected for the assert
            errs["send"] = e

    t = threading.Thread(target=side0, daemon=True)
    t.start()
    with pytest.raises(RuntimeError, match="mismatch"):
        ep1.open_channels([], [(0, k_recv)])
    t.join(timeout=10)
    assert isinstance(errs.get("send"), (TimeoutError, RuntimeError)), \
        "the un-ACKed sender must fail its negotiation too"


def test_endpoint_channels_cached_per_key(endpoints):
    """Repeated open_channels with the same (peer, key) reuses the live
    channel objects — plans rebuilt across traces must not leak channels."""
    ep0, ep1 = endpoints
    key = ("allreduce", (4,), "float32", None)

    def side1():
        for _ in range(2):
            ep1.open_channels([(0, key)], [(0, key)])

    t = threading.Thread(target=side1, daemon=True)
    t.start()
    tx_a, rx_a = ep0.open_channels([(1, key)], [(1, key)])
    tx_b, rx_b = ep0.open_channels([(1, key)], [(1, key)])
    t.join(timeout=10)
    assert tx_a[1] is tx_b[1] and rx_a[1] is rx_b[1]
    assert len(ep0._channels) == 2   # one tx + one rx, not four


# ---------------------------------------------------------------------------
# endpoint: tag matching, epochs, barrier — two endpoints in one process
# ---------------------------------------------------------------------------

class _PairTransport(base.Transport):
    kind = "sock"

    def __init__(self, wires):
        self._wires = wires

    def wire(self, peer):
        return self._wires[peer]

    def close(self):
        for w in self._wires.values():
            w.close()


@pytest.fixture()
def endpoints():
    from repro.transport.endpoint import Endpoint
    s0, s1 = socket.socketpair()
    ep0 = Endpoint(_PairTransport({1: SockWire(s0)}), 0, 2, timeout=1.0)
    ep1 = Endpoint(_PairTransport({0: SockWire(s1)}), 1, 2, timeout=1.0)
    yield ep0, ep1
    ep0.close()
    ep1.close()


def test_endpoint_tag_matching_out_of_order(endpoints):
    ep0, ep1 = endpoints
    a, b = np.arange(3.0), np.arange(4) + 10
    ep0.send_array(1, a, tag=5)
    ep0.send_array(1, b, tag=3)
    # tag 3 arrived second but is claimable first; tag 5 stays pending.
    np.testing.assert_array_equal(ep1.recv_array(0, 3), b)
    np.testing.assert_array_equal(ep1.recv_array(0, 5), a)


def test_endpoint_obj_and_barrier(endpoints):
    ep0, ep1 = endpoints
    ep0.send_obj(1, ("payload", 42))
    assert ep1.recv_obj(0) == ("payload", 42)
    done = []
    t = threading.Thread(target=lambda: (ep1.barrier(), done.append(1)),
                         daemon=True)
    t.start()
    ep0.barrier()
    t.join(timeout=5)
    assert done == [1]


def test_endpoint_epoch_discards_stale_frames(endpoints):
    ep0, ep1 = endpoints
    ep0.send_array(1, np.arange(3.0), tag=5)   # epoch 0: will go stale
    ep1.bump_epoch()                           # ep1 now only accepts epoch 1
    with pytest.raises(TimeoutError, match="no frame"):
        ep1.recv_array(0, 5)
    ep0.bump_epoch()
    fresh = np.arange(4.0) + 1
    ep0.send_array(1, fresh, tag=5)
    np.testing.assert_array_equal(ep1.recv_array(0, 5), fresh)


def test_endpoint_future_epoch_stays_pending(endpoints):
    ep0, ep1 = endpoints
    ep0.bump_epoch()                           # ep0 runs ahead
    future = np.arange(5.0)
    ep0.send_array(1, future, tag=9)
    with pytest.raises(TimeoutError):          # not claimable at epoch 0 ...
        ep1.recv_array(0, 9)
    ep1.bump_epoch()                           # ... but kept, not dropped
    np.testing.assert_array_equal(ep1.recv_array(0, 9), future)


def test_endpoint_peer_close_is_an_error(endpoints):
    ep0, ep1 = endpoints
    ep0.close()
    with pytest.raises(RuntimeError, match="closed its wire"):
        ep1.recv_array(0, 5)


# ---------------------------------------------------------------------------
# launcher hardening: crash/timeout containment, zero orphans
# ---------------------------------------------------------------------------

def _assert_all_dead(job):
    for p in job.procs:
        assert p.poll() is not None, f"worker pid {p.pid} still running"
    for pid in job.pids():
        # reparented orphans would still answer signal 0
        try:
            os.kill(pid, 0)
        except ProcessLookupError:
            continue
        # pid exists: must be our own zombie already reaped by Popen.wait
        assert False, f"orphan worker pid {pid} survived teardown"


def test_launcher_kill_mid_collective_no_orphans():
    """SIGKILL one worker of a live shm job mid-barrier: wait() must raise
    promptly, every other worker must be reaped, and every shared-memory
    segment must be unlinked."""
    from repro.transport import launch, WorkerFailure

    job = launch(2, "repro.transport.testing:_spin_entry", transport="shm",
                 args={"seconds": 120}, timeout=60)
    try:
        time.sleep(2.0)  # let the mesh come up and the barrier loop spin
        os.kill(job.pids()[1], signal.SIGKILL)
        t0 = time.monotonic()
        with pytest.raises(WorkerFailure, match="rank 1"):
            job.wait()
        assert time.monotonic() - t0 < 30, "dead worker detected too slowly"
        _assert_all_dead(job)
        from multiprocessing import shared_memory
        from repro.transport.shm import segment_name
        for i, j in ((0, 1), (1, 0)):
            with pytest.raises(FileNotFoundError):
                shared_memory.SharedMemory(
                    name=segment_name(job.session, i, j))
    finally:
        job.close()


def test_launcher_job_timeout_reaps_workers():
    from repro.transport import launch

    job = launch(2, "repro.transport.testing:_spin_entry",
                 args={"seconds": 120}, timeout=6)
    try:
        with pytest.raises(TimeoutError, match="exceeded 6s"):
            job.wait()
        _assert_all_dead(job)
    finally:
        job.close()


def test_launcher_worker_exception_carries_transcript():
    from repro.transport import launch, WorkerFailure

    job = launch(2, "repro.transport.testing:_case_entry",
                 args={"module": "tests.no_such_module"}, timeout=60)
    try:
        with pytest.raises(WorkerFailure, match="no_such_module"):
            job.wait()
        _assert_all_dead(job)
    finally:
        job.close()


def test_launcher_rejects_bad_arguments():
    from repro.transport import launch

    with pytest.raises(ValueError, match="transport"):
        launch(2, "mod:fn", transport="carrier-pigeon")
    with pytest.raises(ValueError, match="module:function"):
        launch(2, "not-an-entry")


# ---------------------------------------------------------------------------
# satellite: plan cache keys carry backend/transport identity
# ---------------------------------------------------------------------------

def test_plan_cache_backend_identity():
    import jax.numpy as jnp

    from repro.core import plans
    from repro.core.comm import Communicator
    from repro.transport.endpoint import MultiprocComm

    emu = Communicator(("ranks",), 0)
    shm = MultiprocComm(("proc",), 0, rank_id=0, nprocs=2,
                        transport_kind="shm")
    sock = MultiprocComm(("proc",), 0, rank_id=0, nprocs=2,
                         transport_kind="sock")
    keys = {plans._backend_key(c) for c in (emu, shm, sock)}
    assert len(keys) == 3, "backend/transport identity must split cache keys"

    plans.plan_cache_clear()
    x = jnp.zeros((4,), jnp.float32)
    p_shm = plans.allreduce_init(x, comm=shm)
    p_sock = plans.allreduce_init(x, comm=sock)
    assert p_shm is not p_sock, "shm plan served to a sock communicator"
    assert plans.allreduce_init(x, comm=sock) is p_sock
    stats = plans.plan_cache_stats()
    assert stats["by_backend"]["multiproc"]["misses"] >= 2
    assert stats["by_backend"]["multiproc"]["hits"] >= 1
