"""Pallas kernel validation (interpret=True on CPU): shape/dtype sweeps +
assert_allclose against the pure-jnp oracles, cross-checks against the
XLA-native model paths, and hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba2_ssd.ops import mamba2_ssd
from repro.kernels.mamba2_ssd.ref import ssd_scan_ref
from repro.kernels.rmsnorm.ops import rmsnorm_fused
from repro.kernels.rmsnorm.ref import rmsnorm_ref

RNG = np.random.default_rng(0)


def randn(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


TOL = {jnp.float32: dict(atol=2e-5, rtol=2e-5),
       jnp.bfloat16: dict(atol=3e-2, rtol=3e-2)}


# ------------------------------------------------------------------ #
# flash attention
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kh,s,d,w,bq,bk", [
    (2, 4, 2, 256, 64, None, 64, 64),     # GQA
    (1, 4, 4, 128, 32, 48, 32, 32),       # MHA + window
    (2, 6, 2, 200, 32, None, 64, 64),     # non-divisible seq (padding)
    (1, 8, 1, 128, 128, None, 64, 64),    # MQA, MXU-aligned head
    (1, 2, 2, 100, 64, 32, 32, 64),       # window + ragged + bq≠bk
])
def test_flash_attention_matches_ref(dtype, b, h, kh, s, d, w, bq, bk):
    q = randn((b, s, h, d), dtype)
    k = randn((b, s, kh, d), dtype)
    v = randn((b, s, kh, d), dtype)
    out = flash_attention(q, k, v, n_kv_heads=kh, window=w, bq=bq, bk=bk)
    ref = attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                        jnp.swapaxes(v, 1, 2), n_kv_heads=kh, window=w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(jnp.swapaxes(ref, 1, 2), np.float32),
                               **TOL[dtype])


def test_flash_attention_matches_model_blockwise():
    """Kernel ↔ XLA-native twin (models.attention.blockwise_sdpa)."""
    from repro.models.attention import blockwise_sdpa
    q = randn((2, 256, 4, 32))
    k = randn((2, 256, 2, 32))
    v = randn((2, 256, 2, 32))
    a = flash_attention(q, k, v, n_kv_heads=2, bq=64, bk=64)
    b_ = blockwise_sdpa(q, k, v, 2, q_block=64, kv_block=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-5,
                               rtol=2e-5)


def test_flash_attention_suffix_decode():
    q = randn((1, 64, 2, 32))
    k = randn((1, 256, 2, 32))
    v = randn((1, 256, 2, 32))
    out = flash_attention(q, k, v, n_kv_heads=2, bq=32, bk=64)
    ref = attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                        jnp.swapaxes(v, 1, 2), n_kv_heads=2)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.swapaxes(ref, 1, 2)),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_property():
    from repro.testing import property_testing
    given, settings, st = property_testing()

    @settings(max_examples=10, deadline=None)
    @given(s=st.integers(16, 128), kh=st.sampled_from([1, 2, 4]),
           g=st.sampled_from([1, 2]), d=st.sampled_from([16, 32]),
           seed=st.integers(0, 999))
    def inner(s, kh, g, d, seed):
        r = np.random.default_rng(seed)
        h = kh * g
        q = jnp.asarray(r.standard_normal((1, s, h, d)), jnp.float32)
        k = jnp.asarray(r.standard_normal((1, s, kh, d)), jnp.float32)
        v = jnp.asarray(r.standard_normal((1, s, kh, d)), jnp.float32)
        out = flash_attention(q, k, v, n_kv_heads=kh, bq=32, bk=32)
        ref = attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                            jnp.swapaxes(v, 1, 2), n_kv_heads=kh)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(jnp.swapaxes(ref, 1, 2)),
                                   atol=3e-5, rtol=3e-5)

    inner()


# ------------------------------------------------------------------ #
# decode attention
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kh,s,d,bk,fill", [
    (2, 4, 2, 512, 64, 128, 300),     # partially filled cache
    (1, 8, 8, 256, 32, 64, 256),      # fully filled
    (4, 4, 1, 300, 64, 128, 123),     # MQA + ragged cache
])
def test_decode_attention_matches_ref(dtype, b, h, kh, s, d, bk, fill):
    q = randn((b, 1, h, d), dtype)
    k = randn((b, s, kh, d), dtype)
    v = randn((b, s, kh, d), dtype)
    valid = (jnp.arange(s) < fill)
    out = decode_attention(q, k, v, valid, n_kv_heads=kh, bk=bk)
    g = h // kh
    ref = decode_attention_ref(q[:, 0].reshape(b, kh, g, d),
                               jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
                               valid)
    np.testing.assert_allclose(np.asarray(out[:, 0].reshape(b, kh, g, d),
                                          np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


def test_decode_attention_ring_mask():
    """Scattered valid slots (SWA ring cache pattern)."""
    b, h, kh, s, d = 1, 2, 2, 128, 32
    q = randn((b, 1, h, d))
    k = randn((b, s, kh, d))
    v = randn((b, s, kh, d))
    valid = jnp.asarray(RNG.integers(0, 2, s), bool)
    valid = valid.at[0].set(True)  # at least one valid slot
    out = decode_attention(q, k, v, valid, n_kv_heads=kh, bk=32)
    ref = decode_attention_ref(q[:, 0].reshape(b, kh, 1, d),
                               jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
                               valid)
    np.testing.assert_allclose(np.asarray(out[:, 0].reshape(b, kh, 1, d)),
                               np.asarray(ref), atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------------ #
# mamba2 SSD
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,H,s,P,N,chunk", [
    (2, 4, 256, 32, 32, 64),
    (1, 2, 100, 16, 64, 32),    # ragged seq
    (2, 8, 128, 64, 16, 128),   # single chunk
])
def test_mamba2_ssd_matches_scan(dtype, b, H, s, P, N, chunk):
    x = randn((b, s, H, P), dtype, 0.5)
    dt = jnp.abs(randn((b, s, H), jnp.float32, 0.3)) + 0.01
    B = randn((b, s, N), dtype, 0.5)
    C = randn((b, s, N), dtype, 0.5)
    A = -jnp.abs(jnp.asarray(RNG.uniform(0.5, 2.0, H), jnp.float32))
    D = jnp.asarray(RNG.standard_normal(H), jnp.float32)
    y, hf = mamba2_ssd(x, dt, B, C, A, D, chunk=chunk)
    yr, hr = ssd_scan_ref(jnp.moveaxis(x, 2, 1), jnp.moveaxis(dt, 2, 1),
                          B, C, A, D)
    tol = dict(atol=5e-4, rtol=5e-3) if dtype == jnp.float32 else \
        dict(atol=5e-2, rtol=5e-2)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(jnp.moveaxis(yr, 1, 2), np.float32),
                               **tol)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hr),
                               atol=1e-3, rtol=1e-2)


def test_mamba2_ssd_matches_model_chunked():
    """Kernel ↔ XLA-native twin (models.ssm.ssd_chunked)."""
    from repro.models.ssm import ssd_chunked
    b, H, s, P, N = 1, 2, 128, 16, 32
    x = randn((b, s, H, P), jnp.float32, 0.5)
    dt = jnp.abs(randn((b, s, H), jnp.float32, 0.3)) + 0.01
    B = randn((b, s, N), jnp.float32, 0.5)
    C = randn((b, s, N), jnp.float32, 0.5)
    A = -jnp.abs(jnp.asarray(RNG.uniform(0.5, 2.0, H), jnp.float32))
    D = jnp.zeros((H,), jnp.float32)
    y_k, h_k = mamba2_ssd(x, dt, B, C, A, D, chunk=32)
    y_m, h_m = ssd_chunked(x, dt, A, B, C, chunk=32)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m), atol=1e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_m), atol=1e-4,
                               rtol=1e-3)


# ------------------------------------------------------------------ #
# fused rmsnorm
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape,br", [((512, 1024), 128), ((3, 7, 256), 64),
                                      ((100, 896), 256)])
def test_rmsnorm_matches_ref(dtype, shape, br):
    x = randn(shape, dtype)
    scale = randn(shape[-1:], jnp.float32)
    out = rmsnorm_fused(x, scale, block_rows=br)
    ref = rmsnorm_ref(x, scale)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


def test_rmsnorm_property():
    from repro.testing import property_testing
    given, settings, st = property_testing()

    @settings(max_examples=15, deadline=None)
    @given(r=st.integers(1, 64), d=st.sampled_from([8, 64, 256]),
           seed=st.integers(0, 999))
    def inner(r, d, seed):
        rg = np.random.default_rng(seed)
        x = jnp.asarray(rg.standard_normal((r, d)), jnp.float32)
        scale = jnp.asarray(rg.standard_normal((d,)), jnp.float32)
        out = rmsnorm_fused(x, scale, block_rows=16)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(rmsnorm_ref(x, scale)),
                                   atol=1e-5, rtol=1e-5)

    inner()


# ------------------------------------------------------------------ #
# MoE grouped matmul
# ------------------------------------------------------------------ #

from repro.kernels.moe_gmm.ops import moe_gmm
from repro.kernels.moe_gmm.ref import moe_gmm_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("e,c,d,f,bc,bf", [
    (4, 64, 32, 48, 32, 16),
    (2, 100, 16, 64, 64, 64),   # ragged C (padding path)
    (8, 32, 64, 30, 16, 16),    # ragged F
])
def test_moe_gmm_matches_ref(dtype, e, c, d, f, bc, bf):
    x = randn((e, c, d), dtype, 0.5)
    w = randn((e, d, f), dtype, 0.5)
    nv = jnp.asarray(RNG.integers(1, c + 1, e), jnp.int32)
    out = moe_gmm(x, w, nv, bc=bc, bf=bf)
    ref = moe_gmm_ref(x, w, nv)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


def test_moe_gmm_matches_moe_ffn_expert_compute():
    """Kernel == the einsum inside models.moe (same contraction)."""
    e, c, d, f = 4, 16, 24, 32
    x = randn((e, c, d))
    w = randn((e, d, f))
    out = moe_gmm(x, w)
    ref = jnp.einsum("ecd,edf->ecf", x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)
