"""Multi-rank distributed-runtime cases (8 emulated devices): pipeline
parallelism, collective matmul overlap, and the jmpi trainer backend (the
paper's technique at trainer scale) vs the single-program GSPMD result.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

import repro.core as jmpi
from repro.core import compat
from repro.distributed.overlap import collective_matmul_ag, collective_matmul_rs
from repro.distributed.pipeline import pipeline_forward

N = 8


def mesh1d():
    return compat.make_mesh((N,), ("stages",))


def case_pipeline_matches_stacked_forward():
    """P=8 stages each applying its own affine layer == stacked composition."""
    rng = np.random.default_rng(0)
    m, d = 4, 16                       # 4 microbatches, width 16
    ws = jnp.asarray(rng.standard_normal((N, d, d)) * 0.3, jnp.float32)
    bs = jnp.asarray(rng.standard_normal((N, d)) * 0.1, jnp.float32)
    x = jnp.asarray(rng.standard_normal((m, 2, d)), jnp.float32)

    mesh = mesh1d()

    @jmpi.spmd(mesh, in_specs=(P(), P("stages"), P("stages")),
               out_specs=P())
    def run(xg, w, b):
        comm = jmpi.world()
        w0, b0 = w[0], b[0]

        def stage_fn(h):
            return jnp.tanh(h @ w0 + b0)

        out = pipeline_forward(xg, stage_fn, comm)
        # only the last stage holds real outputs; share them with a psum
        # (earlier stages contribute zeros)
        mask = (comm.rank() == comm.size() - 1).astype(out.dtype)
        _, out = jmpi.allreduce(out * mask)
        return out

    got = run(x, ws, bs)

    want = x
    for i in range(N):
        want = jnp.tanh(want @ ws[i] + bs[i])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def case_collective_matmul_ag_matches():
    rng = np.random.default_rng(1)
    m, k, p = 32, 16, 24               # m split over 8 ranks
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, p)), jnp.float32)
    mesh = mesh1d()

    @jmpi.spmd(mesh, in_specs=(P("stages"), P()), out_specs=P())
    def run(xs, w):
        return collective_matmul_ag(xs, w, jmpi.world())

    got = run(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                               rtol=1e-4, atol=1e-5)


def case_collective_matmul_rs_matches():
    rng = np.random.default_rng(2)
    m, k, p = 16, 64, 8                # k split over 8 ranks
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, p)), jnp.float32)
    mesh = mesh1d()

    @jmpi.spmd(mesh, in_specs=(P(None, "stages"), P("stages")),
               out_specs=P("stages"))
    def run(xs, ws):
        return collective_matmul_rs(xs, ws, jmpi.world())

    got = run(x, w)                    # (m, p) assembled from rank shards
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                               rtol=1e-4, atol=1e-4)


def case_matmul_allgather_policy_routes():
    """Registry-aware overlap entry point: whatever schedule the active
    policy routes the allgather to, the result matches the plain matmul —
    and forcing ring via the policy demonstrably takes the overlapped path
    (same numerics, collective_permute lowering)."""
    from repro.core import registry
    from repro.distributed.overlap import matmul_allgather

    rng = np.random.default_rng(3)
    m, k, p = 32, 16, 24
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, p)), jnp.float32)
    mesh = mesh1d()

    for algo in ("xla_native", "ring"):
        # fresh function per policy: a shared jitted fn would hit the jit
        # cache on the second iteration and never re-trace under the new
        # policy (selection happens at trace time)
        @jmpi.spmd(mesh, in_specs=(P("stages"), P()), out_specs=P())
        def run(xs, w):
            return matmul_allgather(xs, w, jmpi.world())

        table = jmpi.PolicyTable(
            rules=[jmpi.PolicyRule("allgather", algo)],
            default={"allgather": "xla_native"})
        prev = registry.active_policy()
        jmpi.set_policy(table)
        try:
            hlo = jax.jit(run).lower(x, w).as_text()
            got = run(x, w)
        finally:
            jmpi.set_policy(prev)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                                   rtol=1e-4, atol=1e-5, err_msg=algo)
        n_perm = hlo.count("collective_permute")
        if algo == "ring":
            assert n_perm >= N - 1, f"ring policy must take the overlapped path ({n_perm})"
        else:
            assert n_perm == 0, f"native policy must not permute ({n_perm})"


def case_jmpi_trainer_matches_gspmd():
    """One train step, tiny model: explicit jmpi DP allreduce inside
    shard_map == GSPMD single-program gradients (same loss, same params)."""
    from repro.configs import get_tiny
    from repro.configs.base import RunConfig, ShapeCell
    from repro.launch.specs import synth_batch
    from repro.models import lm as lm_lib
    from repro.train import optim
    from repro.train.trainer import build_jmpi_train_step, build_train_step

    cfg = get_tiny("yi-6b")
    cfg.dtype = "float32"
    rc = RunConfig(learning_rate=1e-2)
    params = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
    opt = optim.init(params, rc)
    batch = synth_batch(cfg, batch=8, seq=16, kind="train")

    mesh = compat.make_mesh((N,), ("data",))

    # jmpi backend
    step = build_jmpi_train_step(cfg, rc, mesh, None)
    comp = jax.tree.map(lambda p: jmpi.init_state(p), params)
    p1, o1, _, loss1 = step(params, opt, comp, batch)

    # gspmd backend (global batch on the same mesh)
    cell = ShapeCell("t", 16, 8, "train")
    bundle = build_train_step(cfg, rc, mesh, cell)
    p2, o2, m2 = bundle.jitted()(params, opt, batch)

    # losses agree
    np.testing.assert_allclose(float(loss1), float(m2["loss"]), rtol=1e-5)
    # updated parameters agree leaf-wise
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-5)


def case_jmpi_trainer_compressed_grads_converge():
    """int8 compressed DP allreduce still reduces loss over steps."""
    from repro.configs import get_tiny
    from repro.configs.base import RunConfig
    from repro.launch.specs import synth_batch
    from repro.models import lm as lm_lib
    from repro.train import optim
    from repro.train.trainer import build_jmpi_train_step

    cfg = get_tiny("yi-6b")
    rc = RunConfig(learning_rate=1e-2, grad_compression_bits=8)
    params = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
    opt = optim.init(params, rc)
    mesh = compat.make_mesh((N,), ("data",))
    step = build_jmpi_train_step(cfg, rc, mesh, None)
    comp = jax.tree.map(lambda p: jmpi.init_state(p), params)
    batch = synth_batch(cfg, batch=8, seq=16, kind="train", seed=0)
    losses = []
    for _ in range(12):   # memorize one batch: loss must fall despite int8
        params, opt, comp, loss = step(params, opt, comp, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses


def case_jmpi_trainer_overlap_bitwise():
    """Backward-overlapped bucketed int8 sync == serial bucketed sync,
    bitwise (ISSUE 8): both orders chain the same per-bucket collectives
    over the same payloads, so params, optimizer state, residuals and loss
    must be identical after several steps — overlap may only move WHEN the
    waits happen, never what is computed."""
    from repro.configs import get_tiny
    from repro.configs.base import RunConfig
    from repro.launch.specs import synth_batch
    from repro.models import lm as lm_lib
    from repro.train import optim
    from repro.train.trainer import build_jmpi_train_step

    cfg = get_tiny("yi-6b")
    cfg.dtype = "float32"
    mesh = compat.make_mesh((N,), ("data",))
    batch = synth_batch(cfg, batch=8, seq=16, kind="train", seed=0)

    def run(overlap):
        rc = RunConfig(learning_rate=1e-2, grad_compression="int8_ef",
                       grad_buckets=4, overlap_grad_sync=overlap)
        params = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
        opt = optim.init(params, rc)
        comp = jax.tree.map(lambda p: jmpi.init_state(p), params)
        step = build_jmpi_train_step(cfg, rc, mesh, None)
        loss = None
        for _ in range(3):
            params, opt, comp, loss = step(params, opt, comp, batch)
        return params, comp, float(loss)

    p_ser, c_ser, l_ser = run(False)
    p_ovl, c_ovl, l_ovl = run(True)
    assert l_ser == l_ovl, (l_ser, l_ovl)
    for a, b in zip(jax.tree.leaves((p_ser, c_ser)),
                    jax.tree.leaves((p_ovl, c_ovl))):
        assert np.array_equal(np.asarray(a), np.asarray(b))
