"""Pytest wrappers for the Cartesian-topology + neighborhood-collective
cases (cart_create/coords/rank/shift/sub, neighbor collectives vs the numpy
oracle under both lowerings, plans/i*-forms, hierarchical allreduce).

Acceptance (ISSUE 3): every case passes for n ∈ {1, 2, 8} ranks.  The case
module is device-count agnostic; each count runs it once in its own child
process (cached transcript).  The 8-rank run is marked slow (quick lane
covers 1 and 2 ranks), mirroring tests/test_plans_multidev.py.
"""

import pytest

from repro.testing import assert_case

pytestmark = pytest.mark.multidev

CASES = [
    "case_cart_create_round_trip",
    "case_cart_create_validation",
    "case_cart_shift_null_semantics",
    "case_cart_sub_groups_and_degenerate_dims",
    "case_halo_exchange_via_neighbor_plan",
    "case_hierarchical_allreduce_matches_oracle",
    "case_ineighbor_unified_requests",
    "case_neighbor_allgather_matches_oracle",
    "case_neighbor_alltoall_2d_matches_oracle",
    "case_neighbor_alltoall_matches_oracle",
    "case_neighbor_alltoallv_ragged_slots",
    "case_neighbor_plans_cache_and_freeze",
]

N_RANKS = [1, 2, pytest.param(8, marks=pytest.mark.slow)]


@pytest.mark.parametrize("n", N_RANKS)
@pytest.mark.parametrize("case", CASES)
def test_topology_case(case, n):
    assert_case("tests.cases_topology", case, n_devices=n)
