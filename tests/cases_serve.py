"""Serving-engine cases — device-count agnostic (run under 1 and 8
emulated devices via tests/test_serve.py, reusing the assert_case child
machinery; the engine itself is single-device, so every count must agree).

Covers the ISSUE-6 tentpole + bugfix satellites: continuous batching over
the paged KV cache is bitwise-equal to one-request-at-a-time padded
generation (full-attention and sliding-window families), the EOS/output
contract holds on both engines (post-EOS masking, early-exit width
padding — the two seed bugs), paged K/V extracted through the block-table
datatype view equals a dense linear cache, blocks/slots recycle to the
exact initial state, admission control serializes under block pressure
instead of failing mid-flight, the engine's gather rows are pinned to the
``core.datatypes.block_table`` view, and the scheduler's FIFO admission
is exercised host-side.
"""

from __future__ import annotations

import dataclasses

import numpy as np

_CTX: dict = {}


def _tiny(family="yi-6b"):
    """Cached (cfg, params) for a tiny model family."""
    if family not in _CTX:
        import jax
        from repro.configs import get_tiny
        from repro.models import lm as lm_lib

        cfg = get_tiny(family)
        _CTX[family] = (cfg, lm_lib.init_params(cfg, jax.random.PRNGKey(0)))
    return _CTX[family]


def _engine():
    """Cached small ContinuousEngine (compiled once per child process)."""
    if "eng" not in _CTX:
        from repro.serve.engine import ContinuousEngine, ServeConfig

        cfg, params = _tiny()
        sc = ServeConfig(max_prompt=16, max_new_tokens=10, eos_id=-1,
                         block_size=4, n_blocks=24, max_slots=4,
                         prefill_chunk=6, prefill_batch=3)
        _CTX["eng"] = ContinuousEngine(cfg, params, sc)
    _CTX["eng"].reset()
    return _CTX["eng"]


# prompt lengths are drawn from a small fixed set so the sequential
# reference engine compiles one prefill per length, not per request
_LENS = (5, 9, 13)


def _prompt(rng, i):
    return rng.integers(0, 256, (_LENS[i % len(_LENS)],), dtype=np.int32)


def _ref(prompt, mnt, family="yi-6b"):
    """Sequential reference: one-request padded generation, first ``mnt``
    tokens (greedy decoding is prefix-consistent in the budget)."""
    from repro.serve.engine import Engine, ServeConfig

    key = ("ref", family)
    if key not in _CTX:
        cfg, params = _tiny(family)
        _CTX[key] = Engine(cfg, params, ServeConfig(
            max_prompt=16, max_new_tokens=10, eos_id=-1))
    out = _CTX[key].generate(np.asarray(prompt, np.int32)[None, :])
    return list(np.asarray(out)[0, :mnt])


def case_continuous_matches_sequential():
    rng = np.random.default_rng(0)
    eng = _engine()
    work = [(_prompt(rng, i), mnt, arr)
            for i, (mnt, arr) in enumerate(
                [(7, 0), (10, 0), (3, 1), (1, 2), (6, 2)])]
    rids = {eng.submit(p, mnt, arrival=arr): (p, mnt)
            for p, mnt, arr in work}
    res = eng.run()
    assert set(res) == set(rids)
    for rid, (p, mnt) in rids.items():
        got = list(res[rid])
        assert got == _ref(p, mnt), (rid, got, _ref(p, mnt))
    # five requests over four slots: at least one slot was recycled
    assert eng.stats["peak_active"] == 4


def case_swa_continuous_matches_sequential():
    from repro.serve.engine import ContinuousEngine, ServeConfig

    cfg, params = _tiny("h2o-danube-3-4b")
    assert cfg.window is not None   # the case exists to cover SWA masking
    eng = ContinuousEngine(cfg, params, ServeConfig(
        max_prompt=16, max_new_tokens=8, eos_id=-1, block_size=4,
        n_blocks=16, max_slots=2, prefill_chunk=6, prefill_batch=2))
    rng = np.random.default_rng(1)
    work = [(_prompt(rng, i), mnt, arr)
            for i, (mnt, arr) in enumerate([(6, 0), (4, 0), (8, 1)])]
    rids = {eng.submit(p, mnt, arrival=arr): (p, mnt)
            for p, mnt, arr in work}
    res = eng.run()
    for rid, (p, mnt) in rids.items():
        assert list(res[rid]) == _ref(p, mnt, "h2o-danube-3-4b")


def case_eos_contract_continuous():
    rng = np.random.default_rng(2)
    eng = _engine()
    width = eng.sc.max_new_tokens
    prompts = np.stack([_prompt(rng, 0) for _ in range(3)])
    prompts[1, 0] ^= 1              # perturb so streams can diverge
    base = np.asarray(eng.generate(prompts))
    assert base.shape == (3, width)

    # rerun with a token observed mid-stream as EOS: same prefix up to and
    # including the first EOS, everything after masked to it, full width
    eos = int(base[0, 2])
    saved = eng.sc
    try:
        eng.sc = dataclasses.replace(saved, eos_id=eos)  # host-side only
        out = np.asarray(eng.generate(prompts))
    finally:
        eng.sc = saved
    assert out.shape == (3, width)
    for r in range(3):
        hits = np.flatnonzero(base[r] == eos)
        first = hits[0] if len(hits) else width - 1
        assert list(out[r, :first + 1]) == list(base[r, :first + 1])
        assert np.all(out[r, first + 1:] == eos)


def case_eos_contract_padded():
    from repro.serve.engine import Engine, ServeConfig

    cfg, params = _tiny()
    rng = np.random.default_rng(3)
    eng = Engine(cfg, params, ServeConfig(max_prompt=16, max_new_tokens=8,
                                          eos_id=-1))
    width = 8
    p0 = _prompt(rng, 0)
    prompts = np.stack([p0, p0])    # identical rows -> identical streams
    base = np.asarray(eng.generate(prompts))
    assert base.shape == (2, width)

    def with_eos(eos):
        saved = eng.sc
        try:
            eng.sc = dataclasses.replace(saved, eos_id=eos)
            return np.asarray(eng.generate(prompts))
        finally:
            eng.sc = saved

    # identical rows emit identical first tokens -> EOS at position 0 on
    # every row -> the early-exit path must still pad to the full width
    # and mask the tail (the two seed bugs)
    out = with_eos(int(base[0, 0]))
    assert out.shape == (2, width)
    assert np.all(out == int(base[0, 0]))

    # mid-stream EOS: prefix preserved, strictly-post-EOS masked
    eos = int(base[0, 3])
    out = with_eos(eos)
    assert out.shape == (2, width)
    for r in range(2):
        hits = np.flatnonzero(base[r] == eos)
        first = hits[0] if len(hits) else width - 1
        assert list(out[r, :first + 1]) == list(base[r, :first + 1])
        assert np.all(out[r, first + 1:] == eos)


def case_paged_equals_dense():
    import jax
    import jax.numpy as jnp
    from repro.models import lm as lm_lib

    cfg, params = _tiny()
    eng = _engine()
    rng = np.random.default_rng(4)
    prompt, mnt = _prompt(rng, 2), 6
    n_kv = len(prompt) + mnt - 1

    snap = {}
    orig = eng.cache.free_slot
    eng.cache.free_slot = lambda s: (snap.update(eng.cache.extract(s, n_kv)),
                                     orig(s))[-1]
    try:
        rid = eng.submit(prompt, mnt)
        res = eng.run()
    finally:
        eng.cache.free_slot = orig

    pre = jax.jit(lambda p, b: lm_lib.prefill(p, cfg, b, 24))
    dec = jax.jit(lambda p, b, c, t: lm_lib.decode_step(p, cfg, b, c, t))
    logits, caches = pre(params, {"tokens": jnp.asarray(prompt[None, :])})
    toks = [int(np.asarray(logits)[0, 0, :cfg.vocab_size].argmax())]
    for i in range(mnt - 1):
        logits, caches = dec(params, {"tokens": jnp.asarray([[toks[-1]]])},
                             caches, len(prompt) + i)
        toks.append(int(np.asarray(logits)[0, 0, :cfg.vocab_size].argmax()))
    np.testing.assert_array_equal(
        np.asarray(caches["main"]["k"])[:, 0, :n_kv], snap["k"])
    np.testing.assert_array_equal(
        np.asarray(caches["main"]["v"])[:, 0, :n_kv], snap["v"])
    assert toks == list(res[rid])


def case_block_recycling():
    rng = np.random.default_rng(5)
    eng = _engine()
    free0 = eng.cache.free_blocks
    v0 = eng.cache.version
    for i in range(6):
        eng.submit(_prompt(rng, i), 4 + (i % 3))
    eng.run()
    assert eng.cache.version > v0            # tables actually churned
    assert eng.stats["peak_active"] == 4     # slots were saturated...
    assert eng.cache.free_blocks == free0    # ...and everything came back
    assert not eng.cache.tables.any()
    assert not eng.cache.n_tokens.any()
    assert eng.sched.idle


def case_admission_under_pressure():
    from repro.serve.engine import ContinuousEngine, ServeConfig

    cfg, params = _tiny()
    # 5 allocatable blocks; each request reserves 3 (5 + 6 - 1 = 10 rows
    # at block_size 4) -> two free slots but block pressure forces the
    # queue to drain strictly one at a time, in FIFO order
    eng = ContinuousEngine(cfg, params, ServeConfig(
        max_prompt=8, max_new_tokens=6, eos_id=-1, block_size=4,
        n_blocks=6, max_slots=2, prefill_chunk=6, prefill_batch=2))
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, 256, (5,), dtype=np.int32) for _ in range(4)]
    rids = [eng.submit(p, 6) for p in prompts]
    res = eng.run()
    assert eng.stats["peak_active"] == 1
    assert list(res) == rids                 # completion kept FIFO order
    for rid, p in zip(rids, prompts):
        assert len(res[rid]) == 6
        assert list(res[rid]) == _ref(p, 6)

    # submit-time rejection of requests that could never be served
    for bad in (lambda: eng.submit(rng.integers(0, 256, (9,), np.int32), 2),
                lambda: eng.submit(prompts[0], 0),
                lambda: eng.submit(prompts[0], 99)):
        try:
            bad()
        except ValueError:
            pass
        else:
            raise AssertionError("expected ValueError at submit")


def case_gather_matches_datatype_view():
    import jax.numpy as jnp

    eng = _engine()
    cache = eng.cache
    cache.alloc_slot(2, 10)
    cache.alloc_slot(0, 5)       # interleave so slot 2's blocks aren't 1..k
    try:
        for slot, n in ((2, 10), (0, 5)):
            view = cache.seq_datatype(slot, n)
            pool_rows = jnp.arange(cache.n_blocks * cache.block_size,
                                   dtype=jnp.int32)
            picked = np.asarray(view.pack(pool_rows))
            np.testing.assert_array_equal(picked,
                                          cache.gather_row(slot)[:n])
    finally:
        cache.free_slot(2)
        cache.free_slot(0)


def case_scheduler_fifo():
    from repro.serve.scheduler import DECODE, PREFILL, Request, Scheduler

    sched = Scheduler(max_slots=2)
    p = np.zeros((4,), np.int32)
    for rid, arr in ((0, 0), (1, 0), (2, 5)):
        sched.submit(Request(rid, p, 3, arrival=arr))

    got = sched.admissible(0, lambda s_, n: True)
    assert [r.rid for r in got] == [0, 1]
    assert all(r.state == PREFILL for r in got)
    assert sched.free_slots == 0
    assert sched.admissible(5, lambda s_, n: True) == []   # no slot free
    assert [r.rid for r in sched.prefills(5)] == [0, 1]

    got[0].state = DECODE
    assert [r.rid for r in sched.decoding()] == [got[0].rid]
    sched.release(got[0])
    assert sched.admissible(4, lambda s_, n: True) == []   # rid 2 not arrived
    assert sched.admissible(5, lambda s_, n: False) == []  # blocks short
    assert [r.rid for r in sched.admissible(5, lambda s_, n: True)] == [2]

    # head-of-line: a blocked head must not be skipped
    sched2 = Scheduler(max_slots=2)
    sched2.submit(Request(0, p, 3))
    sched2.submit(Request(1, p, 3))
    calls = []
    assert sched2.admissible(
        0, lambda s_, n: (calls.append(n), False)[-1]) == []
    assert len(calls) == 1                   # stopped at the blocked head
