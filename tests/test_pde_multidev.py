"""Pytest wrappers for the multi-rank PDE cases (8 emulated devices)."""

import pytest

from repro.testing import run_cases

CASES = [
    "case_halo_exchange_matches_roll",
    "case_cahn_hilliard_matches_oracle",
    "case_mpdata_matches_oracle_all_layouts",
    "case_mpdata_conservation_and_positivity",
    "case_cahn_hilliard_conserves_mass_when_k0",
]


@pytest.mark.parametrize("case", CASES)
def test_pde_case(case):
    run_cases("tests.cases_pde", n_devices=8, only=case)
