"""Pytest wrappers for the multi-rank PDE cases (8 emulated devices)."""

import pytest

from repro.testing import assert_case

pytestmark = pytest.mark.multidev

CASES = [
    "case_halo_exchange_matches_roll",
    "case_cahn_hilliard_matches_oracle",
    "case_mpdata_matches_oracle_all_layouts",
    "case_mpdata_conservation_and_positivity",
    "case_cahn_hilliard_conserves_mass_when_k0",
    "case_cahn_hilliard_diagnostics_mass",
]


@pytest.mark.parametrize("case", CASES)
def test_pde_case(case):
    assert_case("tests.cases_pde", case, n_devices=8)
