"""Suite smoke runs through the real CLI (one child process per suite).

Every registered suite executes at tiny sizes (``--quick --repeats 1``)
via ``python -m repro.bench`` — exactly the path CI's perf-gate uses — and
must produce a schema-valid artifact.  One CLI child per suite is cached
for the whole test process (same trick as ``repro.testing.module_results``)
so parametrized assertions don't re-pay the run.

The acceptance path is covered explicitly: the p2p artifact round-trips
through ``repro.bench.compare`` (pass against a self-captured baseline,
fail on an injected 2x slowdown at the default threshold) and the fresh
run is gated against the *committed* ``benchmarks/baselines`` with a
load-tolerant threshold (the tight default applies on the dedicated CI
runner, not under a parallel test suite).
"""

from __future__ import annotations

import functools
import json
import os
import subprocess
import sys
import tempfile

import pytest

from repro.bench import schema
from repro.bench.compare import compare_docs, main as compare_main
from repro.bench.suites import SUITES

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
SUITE_TIMEOUT = 900


def _env() -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (SRC, env.get("PYTHONPATH", "")) if p)
    # the CLI parent pins the per-suite device count itself; a leaked
    # count here would fight it
    env.pop("XLA_FLAGS", None)
    return env


@functools.lru_cache(maxsize=None)
def run_suite_cli(name: str):
    """Run one suite via the CLI (cached); returns (proc, artifact|None)."""
    fd, out = tempfile.mkstemp(suffix=".json")
    os.close(fd)
    os.unlink(out)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.bench", "--suite", name, "--quick",
         "--repeats", "1", "--warmup", "0", "--json", out],
        env=_env(), capture_output=True, text=True, timeout=SUITE_TIMEOUT,
        cwd=REPO)
    doc = None
    if os.path.exists(out):
        with open(out) as f:
            doc = json.load(f)
        os.unlink(out)
    return proc, doc


@pytest.mark.parametrize("name", sorted(SUITES))
def test_suite_smoke(name):
    proc, doc = run_suite_cli(name)
    assert proc.returncode == 0, (
        f"suite {name} failed:\n{proc.stdout}\n{proc.stderr}")
    assert doc is not None, f"suite {name} wrote no artifact"
    problems = schema.validate(doc)
    assert not problems, f"suite {name} artifact invalid: {problems}"
    assert doc["suite"] == name and doc["rows"]
    assert doc["env"]["device_count"] == SUITES[name].n_devices
    bad = [k for k, ok in doc["invariants"].items() if not ok]
    assert not bad, f"suite {name} invariant failures: {bad}\n{proc.stdout}"


def test_collectives_smoke_invariants():
    """The CI schema-smoke replacement for the old grep checks: the
    collectives artifact must carry both machine-checked invariants."""
    _, doc = run_suite_cli("collectives")
    assert doc is not None
    assert doc["invariants"].get("plan_reuse") is True
    assert doc["invariants"].get("policy_derived") is True
    names = {r["name"] for r in doc["rows"]}
    assert "persistent_plan_cache_hits" in names
    assert any(n.startswith("sweep_allreduce_") for n in names)


def test_p2p_acceptance_artifact(tmp_path):
    """ISSUE-4 acceptance: `--suite p2p --quick --json out.json` produces a
    schema-valid artifact; compare passes against a baseline captured from
    it and fails once a 2x slowdown is injected (default threshold)."""
    proc, doc = run_suite_cli("p2p")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    schema.assert_valid(doc)
    names = {r["name"] for r in doc["rows"]}
    assert {"p2p_latency", "p2p_bandwidth",
            "p2p_multiproc_latency", "p2p_multiproc_bw"} <= names

    cur_dir, base_dir = tmp_path / "cur", tmp_path / "base"
    cur_dir.mkdir()
    schema.dump(doc, str(cur_dir / "BENCH_p2p.json"))
    assert compare_main(["--current", str(cur_dir), "--baselines",
                         str(base_dir), "--update-baselines"]) == 0
    assert compare_main(["--current", str(cur_dir),
                         "--baselines", str(base_dir)]) == 0

    slow = json.loads(json.dumps(doc))
    for row in slow["rows"]:
        if row["unit"] in schema.TIME_UNITS:
            row["value"] *= 2.0
    schema.dump(slow, str(cur_dir / "BENCH_p2p.json"))
    assert compare_main(["--current", str(cur_dir),
                         "--baselines", str(base_dir)]) == 1


def test_p2p_vs_committed_baselines():
    """A fresh quick run gates green against the committed baselines.

    Threshold 4x / floor 50us: this runs with --repeats 1 inside a loaded
    test process, so it checks baseline compatibility (keys, units, env
    handling), while the tight DEFAULT_THRESHOLD gate runs on the
    dedicated CI perf-gate runner with full repeats.
    """
    _, doc = run_suite_cli("p2p")
    base_path = os.path.join(REPO, "benchmarks", "baselines", "p2p.json")
    assert os.path.exists(base_path), "committed p2p baseline missing"
    baseline = schema.load(base_path)
    failures, report = compare_docs(doc, baseline, threshold=4.0,
                                    floor_us=50.0)
    assert failures == [], "\n".join(failures + report)


def test_cli_list_and_errors():
    out = subprocess.run(
        [sys.executable, "-m", "repro.bench", "--list"],
        env=_env(), capture_output=True, text=True, timeout=120, cwd=REPO)
    assert out.returncode == 0
    for name in SUITES:
        assert name in out.stdout

    bad = subprocess.run(
        [sys.executable, "-m", "repro.bench", "--suite", "nope"],
        env=_env(), capture_output=True, text=True, timeout=120, cwd=REPO)
    assert bad.returncode != 0
    assert "unknown suite" in bad.stderr + bad.stdout

    multi = subprocess.run(
        [sys.executable, "-m", "repro.bench", "--suite", "p2p,kernels",
         "--json", "x.json"],
        env=_env(), capture_output=True, text=True, timeout=120, cwd=REPO)
    assert multi.returncode != 0
    assert "--out-dir" in multi.stderr + multi.stdout
