"""Multi-rank jmpi cases (run under 8 emulated devices via repro.testing).

Each ``case_*`` function mirrors one slice of the numba-mpi v1.0 test matrix
(paper §2.5): wrapper↔MPI mapping, dtype coverage, contiguity handling,
JIT-enabled and JIT-disabled execution.
"""

from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)  # full dtype matrix (child proc only)

import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

import repro.core as jmpi
from repro.core import compat
from repro.core import ref

import os

# Under the multiproc backend the launcher sets JMPI_BACKEND/JMPI_NP and the
# world size is the real process count; otherwise the emulated 8-device mesh.
_BACKEND = os.environ.get("JMPI_BACKEND", "emulated")
N = int(os.environ["JMPI_NP"]) if _BACKEND == "multiproc" else 8
DTYPES = [jnp.float32, jnp.float64, jnp.int32, jnp.int64, jnp.complex64,
          jnp.bfloat16]


def mesh1d():
    return compat.make_mesh((N,), ("ranks",))


def mesh2d():
    return compat.make_mesh((2, 4), ("a", "b"))


def shards_of(out):
    return [np.asarray(out[i]) for i in range(out.shape[0])]


def spmd_collective(fn, shards, out_shape_factor=1):
    """Run fn(rank_local_block) on every rank; return per-rank results."""
    if _BACKEND == "multiproc":
        from repro.transport.testing import run_collective
        return run_collective(fn, shards)
    mesh = mesh1d()

    @jmpi.spmd(mesh, in_specs=P("ranks"), out_specs=P("ranks"))
    def run(x):
        y = fn(x[0])
        return y[None]

    glob = jnp.stack(shards)
    return shards_of(run(glob))


def rand(shape, dtype, seed=0):
    rng = np.random.default_rng(seed)
    if jnp.issubdtype(dtype, jnp.complexfloating):
        x = rng.standard_normal(shape) + 1j * rng.standard_normal(shape)
    elif jnp.issubdtype(jnp.dtype(dtype), np.integer):
        x = rng.integers(-20, 20, size=shape)
    else:
        x = rng.standard_normal(shape)
    return np.asarray(jnp.asarray(x, dtype=dtype))


# ---------------------------------------------------------------------- #
# identity / environment
# ---------------------------------------------------------------------- #

def case_rank_size_initialized():
    assert jmpi.initialized()
    mesh = mesh1d()

    @jmpi.spmd(mesh, in_specs=P("ranks"), out_specs=P("ranks"))
    def f(x):
        r = jmpi.rank()
        assert jmpi.size() == N  # static int at trace time
        return (x[0] * 0 + r)[None]

    out = f(jnp.zeros((N, 1), jnp.int32))
    np.testing.assert_array_equal(np.asarray(out).ravel(), np.arange(N))


def case_wtime():
    t0 = jmpi.wtime()
    t1 = jmpi.wtime()
    assert t1 >= t0


# ---------------------------------------------------------------------- #
# p2p
# ---------------------------------------------------------------------- #

def case_sendrecv_ring_all_dtypes():
    for dt in DTYPES:
        src = [rand((3, 2), dt, seed=i) for i in range(N)]

        def ring(x):
            comm = jmpi.world()
            status, y = jmpi.sendrecv(x, pairs=comm.ring_perm(1))
            assert status == jmpi.SUCCESS
            return y

        got = spmd_collective(ring, src)
        want = ref.ppermute(src, [(i, (i + 1) % N) for i in range(N)])
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w, err_msg=f"dtype={dt}")


def case_listing5_exchange():
    """Paper Listing 5: ranks 0 and 1 exchange buffers via isend/irecv+waitall."""
    src = [rand((100,), jnp.float64, seed=i) for i in range(N)]

    def exchange(x):
        req = jmpi.isendrecv(x, pairs=[(0, 1), (1, 0)], tag=11)
        status, [y] = jmpi.waitall([req])
        assert status == jmpi.SUCCESS
        return y

    got = spmd_collective(exchange, src)
    np.testing.assert_array_equal(got[0], src[1])
    np.testing.assert_array_equal(got[1], src[0])
    for i in range(2, N):
        np.testing.assert_array_equal(got[i], np.zeros_like(src[i]))


def case_send_recv_blocking_pair():
    src = [rand((4, 4), jnp.float32, seed=10 + i) for i in range(N)]

    def f(x):
        status, y = jmpi.recv(x, source=2, dest=5, tag=7)
        assert status == jmpi.SUCCESS
        return y

    got = spmd_collective(f, src)
    np.testing.assert_array_equal(got[5], src[2])


def case_isend_wait_test_variants():
    src = [rand((6,), jnp.float32, seed=20 + i) for i in range(N)]

    def f(x):
        r1 = jmpi.isendrecv(x, pairs=[(0, 3)], tag=1)
        r2 = jmpi.isendrecv(x * 2, pairs=[(1, 4)], tag=2)
        st, flag, v1 = jmpi.test(r1)
        assert st == jmpi.SUCCESS
        st, idx, v2 = jmpi.waitany([r2])
        assert idx == 0
        return v1 + v2

    got = spmd_collective(f, src)
    np.testing.assert_allclose(got[3], src[0], rtol=1e-6)
    np.testing.assert_allclose(got[4], 2 * src[1], rtol=1e-6)


def case_p2p_trace_time_topology_errors():
    src = [rand((2,), jnp.float32, seed=i) for i in range(N)]

    def bad(x):
        status, y = jmpi.sendrecv(x, pairs=[(0, 1), (0, 2)])  # src 0 twice
        return y

    try:
        spmd_collective(bad, src)
    except Exception as e:
        assert "injective" in str(e)
    else:
        raise AssertionError("expected trace-time topology error")


def case_p2p_tag_matching():
    """Waiting with the posted tag (or ANY_TAG) succeeds; a mismatched tag
    is a trace-time error — MPI would leave the recv unmatched, our static
    discipline surfaces it during trace."""
    src = [rand((3,), jnp.float32, seed=160 + i) for i in range(N)]

    def good(x):
        r1 = jmpi.isendrecv(x, pairs=[(0, 1)], tag=7)
        r2 = jmpi.isendrecv(x * 2, pairs=[(2, 3)], tag=9)
        _, a = jmpi.wait(r1, tag=7)          # exact match
        _, b = jmpi.wait(r2, tag=jmpi.ANY_TAG)  # wildcard
        return a + b

    got = spmd_collective(good, src)
    np.testing.assert_allclose(got[1], src[0], rtol=1e-6)
    np.testing.assert_allclose(got[3], 2 * src[2], rtol=1e-6)

    def bad(x):
        req = jmpi.isendrecv(x, pairs=[(0, 1)], tag=7)
        _, y = jmpi.wait(req, tag=8)         # wrong tag
        return y

    try:
        spmd_collective(bad, src)
    except Exception as e:
        assert "tag mismatch" in str(e)
    else:
        raise AssertionError("expected trace-time tag mismatch error")


def case_p2p_err_truncate():
    """Undersized recv view → ERR_TRUNCATE status, leading elements land
    (MPI truncation semantics); oversized view → SUCCESS, untouched slots
    keep their prior contents."""
    src = [rand((4, 4), jnp.float32, seed=170 + i) for i in range(N)]

    def small_recv(x):
        dst = jnp.full((2, 3), -1.0, x.dtype)
        dview = jmpi.View(dst, (slice(0, 2), slice(0, 3)))
        req = jmpi.isendrecv(x, pairs=[(0, 1)], recv_into=dview)
        status, y = jmpi.wait(req)
        # status is a static python int; fold it into the payload so the
        # parent can assert it from the per-rank results
        return y + 1000.0 * (status == jmpi.ERR_TRUNCATE)

    got = spmd_collective(small_recv, src)
    want = src[0].ravel()[:6].reshape(2, 3) + 1000.0  # truncated + flagged
    np.testing.assert_allclose(got[1], want, rtol=1e-5)

    def big_recv(x):
        dst = jnp.full((5, 5), -1.0, x.dtype)
        dview = jmpi.View(dst, (slice(0, 5), slice(0, 5)))
        req = jmpi.isendrecv(x, pairs=[(0, 1)], recv_into=dview)
        status, y = jmpi.wait(req)
        assert status == jmpi.SUCCESS
        return y

    got = spmd_collective(big_recv, src)
    flat = np.asarray(got[1]).ravel()
    np.testing.assert_allclose(flat[:16], src[0].ravel(), rtol=1e-6)
    np.testing.assert_allclose(flat[16:], -1.0)  # untouched slots preserved


def case_waitany_testany_ordering():
    """'any' completes deterministically in ISSUE order (index 0 first);
    later requests stay pending and complete with their own payloads."""
    src = [rand((5,), jnp.float32, seed=180 + i) for i in range(N)]

    def f(x):
        r1 = jmpi.isendrecv(x, pairs=[(0, 2)], tag=1)
        r2 = jmpi.isendrecv(x * 3, pairs=[(1, 4)], tag=2)
        st, idx, v1 = jmpi.waitany([r1, r2])
        assert st == jmpi.SUCCESS and idx == 0
        st, flag, idx2, v2 = jmpi.testany([r2])
        assert idx2 == 0  # static index; flag is a traced always-True bool
        return v1 + v2 + jnp.where(flag, 0.0, jnp.nan).astype(x.dtype)

    got = spmd_collective(f, src)
    np.testing.assert_allclose(got[2], src[0], rtol=1e-6)
    np.testing.assert_allclose(got[4], 3 * src[1], rtol=1e-6)


# ---------------------------------------------------------------------- #
# collectives vs numpy oracle
# ---------------------------------------------------------------------- #

def case_allreduce_operators():
    for op, name in [(jmpi.Operator.SUM, "sum"), (jmpi.Operator.MIN, "min"),
                     (jmpi.Operator.MAX, "max"), (jmpi.Operator.PROD, "prod")]:
        src = [rand((2, 3), jnp.float64, seed=30 + i) for i in range(N)]
        got = spmd_collective(
            lambda x, op=op: jmpi.allreduce(x, op)[1], src)
        want = ref.allreduce([np.asarray(s) for s in src], name)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-10, err_msg=name)


def case_allreduce_logical():
    src = [np.asarray(rand((5,), jnp.int32, seed=40 + i) % 2) for i in range(N)]
    for op, name in [(jmpi.Operator.LAND, "land"), (jmpi.Operator.LOR, "lor")]:
        got = spmd_collective(lambda x, op=op: jmpi.allreduce(x, op)[1],
                              [jnp.asarray(s) for s in src])
        want = ref.allreduce(src, name)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w, err_msg=name)


def case_bcast_all_dtypes():
    for dt in DTYPES:
        src = [rand((3, 3), dt, seed=50 + i) for i in range(N)]
        got = spmd_collective(lambda x: jmpi.bcast(x, root=3)[1], src)
        want = ref.bcast(src, root=3)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w, err_msg=str(dt))


def case_scatter_gather_allgather():
    src = [rand((N * 2, 3), jnp.float32, seed=60 + i) for i in range(N)]
    got = spmd_collective(lambda x: jmpi.scatter(x, root=1)[1], src)
    want = ref.scatter(src, root=1)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)

    small = [rand((2, 3), jnp.float32, seed=70 + i) for i in range(N)]
    got = spmd_collective(lambda x: jmpi.allgather(x)[1], small)
    want = ref.allgather(small)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)

    got = spmd_collective(lambda x: jmpi.gather(x, root=0)[1], small)
    want = ref.gather(small, root=0)
    np.testing.assert_array_equal(got[0], want[0])


def case_alltoall_reduce_scatter():
    src = [rand((N, 4), jnp.float32, seed=80 + i) for i in range(N)]
    got = spmd_collective(lambda x: jmpi.alltoall(x)[1], src)
    want = ref.alltoall(src)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)

    src = [rand((N * 2,), jnp.float32, seed=90 + i) for i in range(N)]
    got = spmd_collective(lambda x: jmpi.reduce_scatter(x)[1], src)
    want = ref.reduce_scatter(src)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-6)


def case_barrier_and_token_sequencing():
    src = [rand((4,), jnp.float32, seed=100 + i) for i in range(N)]

    def f(x):
        comm = jmpi.world()
        _, a = jmpi.sendrecv(x, pairs=comm.ring_perm(1))
        assert jmpi.barrier() == jmpi.SUCCESS
        _, b = jmpi.sendrecv(a, pairs=comm.ring_perm(1))
        return b

    got = spmd_collective(f, src)
    want = ref.ppermute(ref.ppermute(src, [(i, (i + 1) % N) for i in range(N)]),
                        [(i, (i + 1) % N) for i in range(N)])
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


# ---------------------------------------------------------------------- #
# non-contiguous views (paper §2.3 / Listing 6)
# ---------------------------------------------------------------------- #

def case_view_strided_send_recv():
    src = [rand((6, 6), jnp.float64, seed=110 + i) for i in range(N)]

    def f(x):
        view = jmpi.View(x, (slice(1, 5), slice(0, 6, 2)))  # strided interior
        dst = jnp.zeros_like(x)
        dview = jmpi.View(dst, (slice(1, 5), slice(0, 6, 2)))
        req = jmpi.isendrecv(view, pairs=[(0, 1)], recv_into=dview)
        _, y = jmpi.wait(req)
        return y

    got = spmd_collective(f, src)
    want = np.zeros_like(np.asarray(src[1]))
    want[1:5, 0:6:2] = np.asarray(src[0])[1:5, 0:6:2]
    np.testing.assert_array_equal(got[1], want)


def case_view_transposed_fortran_analogue():
    src = [rand((4, 8), jnp.float32, seed=120 + i) for i in range(N)]

    def f(x):
        xt = x.T  # Fortran-order analogue (DESIGN.md §2)
        _, y = jmpi.sendrecv(jmpi.View(xt, (slice(None), slice(1, 3))),
                             pairs=[(2, 0)])
        return y

    got = spmd_collective(f, src)
    np.testing.assert_array_equal(got[0], np.asarray(src[2]).T[:, 1:3])


# ---------------------------------------------------------------------- #
# communicators over mesh-axis subsets (beyond v1.0)
# ---------------------------------------------------------------------- #

def case_subcommunicators_2d():
    mesh = mesh2d()

    @jmpi.spmd(mesh, in_specs=P("a", "b"), out_specs=(P("a", "b"), P("a", "b")))
    def f(x):
        x = x[0, 0]
        world = jmpi.world()
        assert world.size() == 8 and world.axes == ("a", "b")
        row = world.split(["b"])   # 2 groups of 4
        col = world.split(["a"])   # 4 groups of 2
        _, rsum = jmpi.allreduce(x, comm=row)
        _, csum = jmpi.allreduce(x, comm=col)
        return rsum[None, None], csum[None, None]

    x = jnp.arange(8.0).reshape(2, 4)
    rsum, csum = f(x)
    np.testing.assert_allclose(np.asarray(rsum),
                               np.broadcast_to(x.sum(1, keepdims=True), (2, 4)))
    np.testing.assert_allclose(np.asarray(csum),
                               np.broadcast_to(np.asarray(x).sum(0), (2, 4)))


def case_multiaxis_world_ppermute():
    mesh = mesh2d()

    @jmpi.spmd(mesh, in_specs=P("a", "b"), out_specs=P("a", "b"))
    def f(x):
        x = x[0, 0]
        comm = jmpi.world()
        _, y = jmpi.sendrecv(x, pairs=comm.ring_perm(1))
        return y[None, None]

    x = jnp.arange(8.0).reshape(2, 4)
    y = np.asarray(f(x)).ravel()
    np.testing.assert_allclose(y, np.roll(np.arange(8.0), 1))


# ---------------------------------------------------------------------- #
# ring schedules & compression (beyond-paper §7)
# ---------------------------------------------------------------------- #

def case_ring_allreduce_matches_psum():
    for numel in (16, 33, 257):  # incl. non-divisible-by-8 sizes
        src = [rand((numel,), jnp.float32, seed=130 + i) for i in range(N)]
        got = spmd_collective(lambda x: jmpi.ring_allreduce(x)[1], src)
        want = ref.allreduce([np.asarray(s) for s in src], "sum")
        for g, w in zip(got, want):
            # fp32 summation order differs between ring and tree schedules
            np.testing.assert_allclose(g, w, rtol=5e-5, atol=1e-6,
                                       err_msg=f"n={numel}")


def case_ring_allgather_matches():
    src = [rand((3, 2), jnp.float32, seed=140 + i) for i in range(N)]
    got = spmd_collective(lambda x: jmpi.ring_allgather(x)[1], src)
    want = ref.allgather([np.asarray(s) for s in src])
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-6)


def case_compressed_allreduce_accuracy_and_feedback():
    rng = np.random.default_rng(0)
    g_global = [rng.standard_normal((64,)).astype(np.float32) for _ in range(N)]
    mean_true = np.mean(np.stack(g_global), axis=0)

    def f(x):
        x = x[0]
        st = jmpi.init_state(x)
        status, red, st2 = jmpi.compressed_allreduce(x, st, bits=8)
        assert status == jmpi.SUCCESS
        return jnp.stack([red, st2.error])[None]

    mesh = mesh1d()
    run = jmpi.spmd(mesh, in_specs=P("ranks"), out_specs=P("ranks"))(f)
    out = run(jnp.stack(g_global)[:, None])
    red = np.asarray(out[0, 0]).ravel()
    err = np.asarray(out[0, 1]).ravel()
    amax = np.abs(np.stack(g_global)).max()
    np.testing.assert_allclose(red, mean_true, atol=2 * amax / 127)
    # error feedback: residual bounded by one quantization level
    assert np.abs(err).max() <= amax / 127 + 1e-6

    # bf16 mode
    def f16(x):
        x = x[0]
        st = jmpi.init_state(x)
        _, red, _ = jmpi.compressed_allreduce(x, st, bits=16)
        return red[None]

    run16 = jmpi.spmd(mesh, in_specs=P("ranks"), out_specs=P("ranks"))(f16)
    red16 = np.asarray(run16(jnp.stack(g_global)[:, None]))[0, 0]
    np.testing.assert_allclose(red16, mean_true, atol=amax / 64)


# ---------------------------------------------------------------------- #
# JIT-disabled debug mode (paper: full functionality with JIT off)
# ---------------------------------------------------------------------- #

def case_disable_jit_debug_mode():
    src = [rand((4,), jnp.float32, seed=150 + i) for i in range(N)]
    with jax.disable_jit():
        got = spmd_collective(lambda x: jmpi.allreduce(x)[1], src)
    want = ref.allreduce([np.asarray(s) for s in src], "sum")
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-6)


# ---------------------------------------------------------------------- #
# property-based (hypothesis) — invariants over shapes/dtypes
# ---------------------------------------------------------------------- #

def case_property_collectives_match_oracle():
    from repro.testing import property_testing
    given, settings, st = property_testing()

    dtypes = st.sampled_from([np.float32, np.float64, np.int32])
    shapes = st.tuples(st.integers(1, 5), st.integers(1, 4))

    @settings(max_examples=15, deadline=None)
    @given(dt=dtypes, shape=shapes, seed=st.integers(0, 2**16),
           op=st.sampled_from(["sum", "min", "max"]))
    def inner(dt, shape, seed, op):
        rng = np.random.default_rng(seed)
        if np.issubdtype(dt, np.integer):
            src = [rng.integers(-9, 9, size=shape).astype(dt) for _ in range(N)]
        else:
            src = [rng.standard_normal(shape).astype(dt) for _ in range(N)]
        opmap = {"sum": jmpi.Operator.SUM, "min": jmpi.Operator.MIN,
                 "max": jmpi.Operator.MAX}
        got = spmd_collective(
            lambda x, o=opmap[op]: jmpi.allreduce(x, o)[1],
            [jnp.asarray(s) for s in src])
        want = ref.allreduce(src, op)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-5)

    inner()


def case_property_permute_roundtrip():
    from repro.testing import property_testing
    given, settings, st = property_testing()

    @settings(max_examples=15, deadline=None)
    @given(shift=st.integers(1, N - 1), seed=st.integers(0, 2**16))
    def inner(shift, seed):
        rng = np.random.default_rng(seed)
        src = [rng.standard_normal((3,)).astype(np.float32) for _ in range(N)]

        def f(x, s=shift):
            comm = jmpi.world()
            _, y = jmpi.sendrecv(x, pairs=comm.ring_perm(s))
            _, z = jmpi.sendrecv(y, pairs=comm.ring_perm(N - s))
            return z

        got = spmd_collective(f, [jnp.asarray(s) for s in src])
        for g, w in zip(got, src):  # shift then unshift == identity
            np.testing.assert_allclose(g, w, rtol=1e-6)

    inner()
