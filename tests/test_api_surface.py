"""API-surface audit (host-side, no devices): the jmpi module-level
wrappers and the ``Communicator`` method surface must stay in sync.

The check is ``__all__``-driven so a routine added to one surface without
the other fails here instead of drifting silently:

1. every *routine-shaped* export in ``repro.core.__all__`` (a collective,
   a v-variant, a p2p call, or one of their ``i*``/``*_init`` forms) must
   exist as a ``Communicator`` method (``CartComm`` for the neighborhood
   family), and
2. every public ``Communicator``/``CartComm`` method that is one of those
   routine shapes must be exported at module level.

Infrastructure names (spmd/world/wait*/token helpers/registry controls)
are module-only by design; identity/topology/pattern helpers are
method-only; both exclusion lists are explicit so additions are a
conscious decision.
"""

from __future__ import annotations

import inspect

import repro.core as jmpi
from repro.core.comm import Communicator
from repro.core.topology import CartComm

# The logical op families.  Routine shapes derived from them: the blocking
# name, the i<name> nonblocking form, and the <name>_init persistent form.
COLLECTIVES = (
    "allreduce", "bcast", "scatter", "gather", "allgather", "alltoall",
    "reduce_scatter", "barrier",
    # v-variants (ISSUE 5)
    "scatterv", "gatherv", "allgatherv", "alltoallv",
)
NEIGHBOR = ("neighbor_allgather", "neighbor_alltoall", "neighbor_alltoallv")
P2P = ("send", "recv", "sendrecv", "isend", "irecv", "isendrecv")

# Module-only infrastructure that legitimately has no method form.
MODULE_ONLY = {
    "sendrecv_init",  # also a method; listed via P2P handling below
}
# Method-only helpers that legitimately have no module-level wrapper.
METHOD_ONLY = {
    "rank", "size", "coords", "axis_sizes", "split", "dup", "cart_create",
    "ring_perm", "pairwise_perm", "neighbor_perm",
    # CartComm topology queries (static coordinate math)
    "cart_coords", "cart_rank", "cart_shift", "cart_shift_perm", "cart_sub",
    "neighbor_ranks",
}


def _routine_names():
    names = []
    for op in COLLECTIVES:
        names.append(op)
        names.append(f"i{op}")
        names.append(f"{op}_init")
    for op in NEIGHBOR:
        names.append(op)
        names.append(f"i{op}")
        names.append(f"{op}_init")
    names.extend(P2P)
    names.append("sendrecv_init")
    return names


def _method_host(name: str):
    return CartComm if name.lstrip("i").startswith("neighbor_") \
        or name.startswith("neighbor_") else Communicator


def test_every_routine_on_both_surfaces():
    """Every routine shape exists in __all__ AND as a communicator method."""
    missing_module, missing_method = [], []
    for name in _routine_names():
        if name not in jmpi.__all__ or not callable(getattr(jmpi, name, None)):
            missing_module.append(name)
        host = _method_host(name)
        if not callable(getattr(host, name, None)):
            missing_method.append(f"{host.__name__}.{name}")
    assert not missing_module, (
        f"routines missing from the jmpi module surface (__all__): "
        f"{missing_module}")
    assert not missing_method, (
        f"routines missing from the method surface: {missing_method}")


def test_no_unexported_routine_methods():
    """Every public op-shaped Communicator/CartComm method is exported at
    module level (__all__) — additions to one surface must land on both."""
    routine_shapes = set(_routine_names())
    problems = []
    for cls in (Communicator, CartComm):
        for name, member in vars(cls).items():
            if name.startswith("_") or not callable(member):
                continue
            if name in METHOD_ONLY:
                continue
            if name in routine_shapes and name not in jmpi.__all__:
                problems.append(f"{cls.__name__}.{name}")
    assert not problems, (
        f"method-surface routines not exported in repro.core.__all__: "
        f"{problems}")


def test_surface_lists_are_complete():
    """Guard the audit itself: any public Communicator method that is
    neither a known routine shape nor an excluded helper fails here, so
    new methods must be classified (routine on both surfaces, or an
    explicit METHOD_ONLY helper)."""
    routine_shapes = set(_routine_names())
    unclassified = []
    for cls in (Communicator, CartComm):
        for name, member in vars(cls).items():
            if name.startswith("_") or not (
                    inspect.isfunction(member) or callable(member)):
                continue
            if isinstance(member, property):
                continue
            if name in routine_shapes or name in METHOD_ONLY \
                    or name in MODULE_ONLY:
                continue
            unclassified.append(f"{cls.__name__}.{name}")
    assert not unclassified, (
        f"unclassified communicator methods (add to the routine families "
        f"or METHOD_ONLY in tests/test_api_surface.py): {unclassified}")


def test_ibarrier_and_plan_forms_callable():
    """Spot-check the generated names actually resolve to callables with
    matching arity conventions (smoke: signatures accept the documented
    keyword-only args)."""
    sig = inspect.signature(jmpi.scatterv)
    assert "counts" in sig.parameters and "algorithm" in sig.parameters
    sig = inspect.signature(Communicator.alltoallv)
    assert "counts" in sig.parameters and "datatype" in sig.parameters
    sig = inspect.signature(jmpi.alltoallv_init)
    assert "counts" in sig.parameters


def test_datatype_kwargs_parity_module_vs_method():
    """The uniform (payload, datatype) contract holds on BOTH surfaces:
    every p2p/collective routine that takes datatype= (and recv_into=) at
    module level takes it as a Communicator method too."""
    drift = []
    i_forms = [f"i{op}" for op in COLLECTIVES if op != "barrier"]
    for name in list(P2P) + list(COLLECTIVES) + i_forms + ["sendrecv_init"]:
        mod_fn = getattr(jmpi, name, None)
        meth = getattr(Communicator, name, None)
        if mod_fn is None or meth is None:
            continue
        mod_params = set(inspect.signature(mod_fn).parameters)
        meth_params = set(inspect.signature(meth).parameters)
        for kw in ("datatype", "recv_into", "counts"):
            if (kw in mod_params) != (kw in meth_params):
                drift.append(f"{name}: {kw} on "
                             f"{'module' if kw in mod_params else 'method'} "
                             f"surface only")
    assert not drift, f"datatype-kwarg drift between surfaces: {drift}"
