"""Bench-core unit tests (host-side, no emulated devices, no jax).

Covers the three pure layers of ``repro.bench``:

1. statistics helpers against numpy oracles;
2. artifact schema: round-trip, validator rejections;
3. the compare gate: pass/fail/threshold edges, unit conversion, the
   min-runtime noise floor, missing rows, smoke mode and the
   ``--update-baselines`` workflow (end-to-end through the CLI ``main``).
"""

from __future__ import annotations

import json
import os

import numpy as np
import pytest

from repro.bench import schema, stats
from repro.bench.compare import (DEFAULT_THRESHOLD, compare_docs,
                                 main as compare_main, smoke_check,
                                 update_baselines)

# ---------------------------------------------------------------------- #
# stats vs numpy oracles
# ---------------------------------------------------------------------- #

SAMPLE_SETS = [
    [3.0],
    [1.0, 2.0],
    [5.0, 1.0, 4.0, 2.0, 3.0],
    list(np.random.default_rng(0).lognormal(0, 1, 17)),
    list(np.random.default_rng(1).uniform(10, 20, 100)),
]


@pytest.mark.parametrize("xs", SAMPLE_SETS, ids=range(len(SAMPLE_SETS)))
def test_stats_match_numpy(xs):
    assert stats.median(xs) == pytest.approx(np.median(xs))
    for q in (0.0, 0.25, 0.5, 0.75, 0.9, 1.0):
        assert stats.quantile(xs, q) == pytest.approx(
            np.quantile(xs, q), rel=1e-12)
    want_iqr = np.quantile(xs, 0.75) - np.quantile(xs, 0.25)
    assert stats.iqr(xs) == pytest.approx(want_iqr, rel=1e-12)
    assert stats.min_of_k(xs) == min(xs)
    assert stats.min_of_k(xs, k=1) == xs[0]
    assert stats.min_of_k(xs, k=3) == min(xs[:3])


def test_summarize_block():
    xs = [4.0, 1.0, 3.0, 2.0]
    s = stats.summarize(xs)
    assert s["n"] == 4 and s["min"] == 1.0 and s["max"] == 4.0
    assert s["mean"] == pytest.approx(2.5)
    assert s["median"] == pytest.approx(np.median(xs))
    assert s["iqr"] == pytest.approx(
        np.quantile(xs, 0.75) - np.quantile(xs, 0.25))


def test_stats_errors():
    with pytest.raises(ValueError):
        stats.median([])
    with pytest.raises(ValueError):
        stats.quantile([1.0], 1.5)
    with pytest.raises(ValueError):
        stats.min_of_k([1.0], k=0)
    with pytest.raises(ValueError):
        stats.min_of_k([])


# ---------------------------------------------------------------------- #
# schema round-trip + validation
# ---------------------------------------------------------------------- #

def _env(device_count=2, quick=True, policy_hash="abc", backend=None):
    env = {"jax": "0.0", "python": "3.10.0", "platform": "cpu",
           "device_count": device_count, "policy_hash": policy_hash,
           "quick": quick}
    if backend is not None:
        env["backend"] = backend
    return env


def _row(name, value, size=0, unit="us", stats_block=True):
    block = None
    if stats_block:
        block = {"n": 3, "min": value, "max": value, "mean": value,
                 "median": value, "p25": value, "p75": value, "iqr": 0.0}
    return {"name": name, "size": size, "bytes": None, "unit": unit,
            "value": value, "trace_ms": 1.0, "stats": block,
            "derived": None}


def _doc(suite="p2p", rows=None, invariants=None, **env_kw):
    rows = rows if rows is not None else [_row("lat", 100.0, size=1024)]
    return schema.make_doc(suite, rows, invariants or {},
                           {"quick": True, "repeats": 3, "warmup": 1},
                           env=_env(**env_kw))


def test_schema_roundtrip(tmp_path):
    doc = _doc(invariants={"ok": True})
    assert schema.validate(doc) == []
    path = str(tmp_path / "BENCH_p2p.json")
    schema.dump(doc, path)
    loaded = schema.load(path)
    assert loaded == json.loads(json.dumps(doc))  # JSON-stable


@pytest.mark.parametrize("mutate,needle", [
    (lambda d: d.pop("suite"), "suite"),
    (lambda d: d.update(schema="bogus/v9"), "schema tag"),
    (lambda d: d["env"].pop("policy_hash"), "policy_hash"),
    (lambda d: d["rows"][0].update(unit="parsecs"), "unknown unit"),
    (lambda d: d["rows"][0].update(value="fast"), "number"),
    (lambda d: d["rows"][0]["stats"].pop("median"), "stats.median"),
    (lambda d: d["rows"][0].update(size="big"), "size"),
    (lambda d: d.update(invariants={"ok": "yes"}), "invariants"),
    (lambda d: d.update(rows="nope"), "rows"),
    (lambda d: d["rows"][0].update(gate="yes"), "gate"),
])
def test_schema_rejects(mutate, needle):
    doc = _doc()
    mutate(doc)
    problems = schema.validate(doc)
    assert problems and any(needle in p for p in problems), problems


def test_dump_refuses_invalid(tmp_path):
    doc = _doc()
    doc.pop("suite")
    with pytest.raises(ValueError):
        schema.dump(doc, str(tmp_path / "bad.json"))


# ---------------------------------------------------------------------- #
# compare gate
# ---------------------------------------------------------------------- #

def test_compare_identical_passes():
    failures, _ = compare_docs(_doc(), _doc())
    assert failures == []


def test_compare_2x_slowdown_fails():
    base = _doc()
    cur = _doc(rows=[_row("lat", 200.0, size=1024)])
    failures, report = compare_docs(cur, base)
    assert len(failures) == 1
    assert "suite median ratio 2.00x" in failures[0]
    assert any("above threshold" in line for line in report)


def test_compare_suite_median_vs_row_cap():
    """One noisy row among many doesn't fail the suite-median gate; a
    catastrophic single row trips the row cap even when the median holds."""
    names = ["a", "b", "c"]
    base = _doc(rows=[_row(n, 100.0, size=1) for n in names])
    noisy = _doc(rows=[_row("a", 100.0, size=1), _row("b", 100.0, size=1),
                       _row("c", 400.0, size=1)])     # 4x: noise-band
    failures, report = compare_docs(noisy, base)
    assert failures == []
    assert any("above threshold" in line for line in report)
    capped = _doc(rows=[_row("a", 100.0, size=1), _row("b", 100.0, size=1),
                        _row("c", 600.0, size=1)])    # 6x > 3*1.75 cap
    failures, report = compare_docs(capped, base)
    assert len(failures) == 1 and "row cap" in failures[0]
    assert any("REGRESSED (row cap)" in line for line in report)
    # uniform 2x: every ratio 2.0 -> suite median 2.0 -> fail
    uniform = _doc(rows=[_row(n, 200.0, size=1) for n in names])
    failures, _ = compare_docs(uniform, base)
    assert failures and "suite median ratio 2.00x" in failures[0]


def test_compare_threshold_edge():
    base = _doc(rows=[_row("lat", 100.0, size=1024)])
    at = _doc(rows=[_row("lat", 100.0 * DEFAULT_THRESHOLD, size=1024)])
    above = _doc(rows=[_row("lat", 100.0 * DEFAULT_THRESHOLD + 0.1,
                            size=1024)])
    assert compare_docs(at, base)[0] == []        # ratio == threshold: pass
    assert compare_docs(above, base)[0] != []     # just above: fail
    # custom threshold overrides the default
    assert compare_docs(at, base, threshold=1.2)[0] != []


def test_compare_floor_skips_noise():
    base = _doc(rows=[_row("tiny", 5.0, size=8)])      # < 30us floor
    cur = _doc(rows=[_row("tiny", 500.0, size=8)])     # 100x "regression"
    failures, report = compare_docs(cur, base)
    assert failures == []
    assert any("below floor" in line for line in report)
    # raising the floor above a real row's baseline un-gates it too
    base2 = _doc(rows=[_row("lat", 100.0, size=1024)])
    cur2 = _doc(rows=[_row("lat", 300.0, size=1024)])
    assert compare_docs(cur2, base2)[0] != []
    assert compare_docs(cur2, base2, floor_us=200.0)[0] == []


def test_compare_backend_mismatch_is_a_hard_wall():
    """A multiproc artifact must never gate against an emulated baseline
    (or vice versa) — one clear failure line, no row comparison at all."""
    base = _doc(backend="emulated")
    cur = _doc(rows=[_row("lat", 99999.0, size=1024)], backend="multiproc")
    failures, report = compare_docs(cur, base)
    assert len(failures) == 1
    assert "backend mismatch" in failures[0]
    assert "'multiproc'" in failures[0] and "'emulated'" in failures[0]
    assert report == []  # refused before any per-row work


def test_compare_backend_defaults_to_emulated():
    """Legacy baselines without an env.backend key compare as emulated."""
    legacy_base = _doc()                 # no backend key at all
    cur = _doc(backend="emulated")
    assert compare_docs(cur, legacy_base)[0] == []
    mp = _doc(backend="multiproc")
    failures, _ = compare_docs(mp, legacy_base)
    assert failures and "backend mismatch" in failures[0]


def test_env_fingerprint_backend_tag(monkeypatch):
    monkeypatch.delenv("JMPI_BACKEND", raising=False)
    assert schema.env_fingerprint(True)["backend"] == "emulated"
    monkeypatch.setenv("JMPI_BACKEND", "multiproc")
    assert schema.env_fingerprint(True)["backend"] == "multiproc"


def test_compare_unit_conversion():
    base = _doc(rows=[_row("step", 1.0, size=64, unit="ms")])
    cur = _doc(rows=[_row("step", 2.0, size=64, unit="ms")])
    failures, _ = compare_docs(cur, base)
    assert failures and "2.00x" in failures[0]


def test_compare_respects_gate_flag():
    """Time-unit rows with gate:false (extras trace/sweep rows) are never
    gated and never trigger missing-row failures."""
    trace = _row("trace_ms", 100.0, size=1, unit="ms", stats_block=False)
    trace["gate"] = False
    base = _doc(rows=[_row("lat", 100.0, size=1), trace])
    slow_trace = json.loads(json.dumps(trace))
    slow_trace["value"] = 1000.0      # 10x trace "regression": reported only
    cur = _doc(rows=[_row("lat", 100.0, size=1), slow_trace])
    assert compare_docs(cur, base)[0] == []
    # disappearing gate:false row is not a missing-row failure either
    cur2 = _doc(rows=[_row("lat", 100.0, size=1)])
    assert compare_docs(cur2, base)[0] == []


def test_compare_ignores_free_units():
    base = _doc(rows=[_row("speedup", 10.0, size=1, unit="x")])
    cur = _doc(rows=[_row("speedup", 1.0, size=1, unit="x")])
    assert compare_docs(cur, base)[0] == []


def test_compare_missing_row_fails_stale_baseline_readable():
    base = _doc(rows=[_row("lat", 100.0, size=1024)])
    cur_missing = _doc(rows=[_row("other", 100.0, size=1024)])
    failures, _ = compare_docs(cur_missing, base)
    assert failures and "missing" in str(failures)
    # A baseline that PREDATES new suite rows fails with ONE readable
    # message naming the rows and the --update-baselines fix — not a
    # per-row wall.
    cur_extra = _doc(rows=[_row("lat", 100.0, size=1024),
                           _row("new_a", 5000.0, size=4),
                           _row("new_a", 5000.0, size=8),
                           _row("new_b", 5000.0, size=4)])
    failures, report = compare_docs(cur_extra, base)
    stale = [f for f in failures if "predates" in f]
    assert len(stale) == 1, failures
    assert "new_a" in stale[0] and "new_b" in stale[0]
    assert "--update-baselines" in stale[0]
    assert any("new row" in line for line in report)


def test_compare_suite_mismatch():
    failures, _ = compare_docs(_doc(suite="p2p"), _doc(suite="halo"))
    assert failures and "suite mismatch" in failures[0]


def test_compare_env_mismatch_noted():
    base = _doc(device_count=8, policy_hash="aaa")
    cur = _doc(device_count=2, policy_hash="bbb")
    failures, report = compare_docs(cur, base)
    assert failures == []
    assert sum("env." in line for line in report) == 2


# ---------------------------------------------------------------------- #
# smoke mode + update-baselines + CLI main
# ---------------------------------------------------------------------- #

def _write(doc, path):
    with open(path, "w") as f:
        json.dump(doc, f)


def test_smoke_check(tmp_path):
    good = str(tmp_path / "BENCH_p2p.json")
    _write(_doc(invariants={"plan_reuse": True}), good)
    assert smoke_check([good]) == []

    bad_inv = str(tmp_path / "BENCH_halo.json")
    _write(_doc(suite="halo", invariants={"oracle": False}), bad_inv)
    assert any("invariant" in f for f in smoke_check([bad_inv]))

    empty = str(tmp_path / "BENCH_empty.json")
    _write(_doc(suite="empty", rows=[]), empty)
    assert any("no rows" in f for f in smoke_check([empty]))

    invalid = str(tmp_path / "BENCH_bad.json")
    doc = _doc()
    doc.pop("env")
    _write(doc, invalid)
    assert any("env" in f for f in smoke_check([invalid]))

    assert smoke_check([]) != []   # nothing found is a failure


def test_update_and_compare_cli_end_to_end(tmp_path, capsys):
    cur_dir = tmp_path / "cur"
    base_dir = tmp_path / "baselines"
    cur_dir.mkdir()
    doc = _doc(invariants={"plan_reuse": True})
    schema.dump(doc, str(cur_dir / "BENCH_p2p.json"))

    # no baseline yet: compare FAILS with one readable line naming the
    # --update-baselines fix (a brand-new suite must not silently pass)
    rc = compare_main(["--current", str(cur_dir),
                       "--baselines", str(base_dir)])
    assert rc == 1
    out = capsys.readouterr().out
    lines = [ln for ln in out.splitlines()
             if "no committed baseline" in ln]
    assert len(lines) == 1 and "--update-baselines" in lines[0], out

    # adopt, then compare: pass
    assert compare_main(["--current", str(cur_dir), "--baselines",
                         str(base_dir), "--update-baselines"]) == 0
    assert (base_dir / "p2p.json").exists()
    assert compare_main(["--current", str(cur_dir),
                         "--baselines", str(base_dir)]) == 0
    assert "compare OK" in capsys.readouterr().out

    # inject a 2x slowdown into every timed row: gate must fail
    slow = json.loads(json.dumps(doc))
    for row in slow["rows"]:
        if row["unit"] in schema.TIME_UNITS:
            row["value"] *= 2.0
    schema.dump(slow, str(cur_dir / "BENCH_p2p.json"))
    rc = compare_main(["--current", str(cur_dir),
                       "--baselines", str(base_dir)])
    assert rc == 1
    assert "REGRESSION" in capsys.readouterr().out

    # smoke mode only needs the current artifacts
    assert compare_main(["--current", str(cur_dir), "--smoke"]) == 0
    bad = json.loads(json.dumps(doc))
    bad["invariants"] = {"plan_reuse": False}
    schema.dump(bad, str(cur_dir / "BENCH_p2p.json"))
    assert compare_main(["--current", str(cur_dir), "--smoke"]) == 1


def test_update_baselines_helper(tmp_path):
    cur = str(tmp_path / "BENCH_kernels.json")
    schema.dump(_doc(suite="kernels"), cur)
    written = update_baselines([cur], str(tmp_path / "b"))
    assert written == [str(tmp_path / "b" / "kernels.json")]
    assert schema.load(written[0])["suite"] == "kernels"


def test_committed_baselines_are_schema_valid():
    """Every committed baseline must parse under the current schema."""
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    base_dir = os.path.join(here, "benchmarks", "baselines")
    names = [n for n in sorted(os.listdir(base_dir))
             if n.endswith(".json")]
    assert names, "no committed baselines found"
    for name in names:
        doc = schema.load(os.path.join(base_dir, name))
        assert doc["suite"] == name[:-5]
        assert doc["rows"]
