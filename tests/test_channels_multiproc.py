"""Persistent-channel lifecycle under REAL processes: the cases in
``tests/cases_channels.py`` run at {sock, shm} x {n=2, n=4}, exercising
channel negotiation, zero-copy plan execution, epoch reuse, the channel-
lowered collectives, static ERR_TRUNCATE, and the zero-meta steady-state
wire-spy assertion — all across genuine process boundaries.

The final test proves teardown hygiene: a completed shm job leaves no
``/dev/shm`` segment behind (ring segments AND the dynamically-named
persistent-channel segments swept by session prefix).
"""

from __future__ import annotations

import os

import pytest

from repro.transport import launcher
from repro.transport.testing import assert_case_multiproc

MODULE = "tests.cases_channels"

CASES = [
    "case_persistent_sendrecv_ring",
    "case_channel_reuse_across_epochs",
    "case_persistent_collectives_match_numpy",
    "case_err_truncate_at_init",
    "case_zero_meta_steady_state",
]

CONFIGS = [("sock", 2), ("shm", 2), ("sock", 4), ("shm", 4)]


@pytest.mark.parametrize("transport,nprocs", CONFIGS,
                         ids=[f"{t}-{n}" for t, n in CONFIGS])
@pytest.mark.parametrize("case", CASES)
def test_channels_multiproc(case, transport, nprocs):
    assert_case_multiproc(MODULE, case, nprocs, transport)


def test_shm_job_leaves_no_segments():
    """After a shm job that negotiated persistent channels exits, no ring
    or channel segment with the job's session prefix survives in /dev/shm."""
    if not os.path.isdir("/dev/shm"):
        pytest.skip("no /dev/shm on this platform")
    job = launcher.launch(2, "repro.transport.testing:_case_entry",
                          transport="shm", args={"module": MODULE},
                          timeout=600.0)
    session = job.session
    try:
        job.wait()
    finally:
        job.close()
    leaked = [n for n in os.listdir("/dev/shm") if n.startswith(session)]
    assert not leaked, f"leaked /dev/shm segments: {leaked}"
