"""Host-side (single-device, no subprocess) tests for the collective-
algorithm registry: policy-table semantics, JSON round-trip, override
validation, tuner policy construction, the ParamSharder collective plan,
View truncation scatter, and the JAX-compat shims."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as jmpi
from repro.core import compat, registry
from repro.core.registry import PolicyRule, PolicyTable


def test_every_op_has_at_least_two_algorithms():
    for op in registry.OPS:
        names = registry.algorithms(op)
        assert registry.DEFAULT_ALGORITHM in names, op
        assert len(names) >= 2, f"{op} needs >=2 interchangeable lowerings: {names}"


def test_policy_rule_matching_and_defaults():
    table = PolicyTable(
        rules=[PolicyRule("allreduce", "recursive_doubling", max_bytes=1024),
               PolicyRule("allreduce", "ring", min_bytes=1 << 20),
               PolicyRule("alltoall", "pairwise", ranks=8)],
        default={"allreduce": "xla_native"})
    assert table.choose("allreduce", 100, 8) == "recursive_doubling"
    assert table.choose("allreduce", 4096, 8) == "xla_native"
    assert table.choose("allreduce", 2 << 20, 8) == "ring"
    assert table.choose("alltoall", 100, 8) == "pairwise"
    assert table.choose("alltoall", 100, 4) == "xla_native"  # ranks pinned
    assert table.choose("bcast", 100, 8) == "xla_native"     # global default


def test_policy_json_roundtrip(tmp_path):
    table = PolicyTable(
        rules=[PolicyRule("bcast", "tree", min_bytes=0, max_bytes=512,
                          ranks=8)],
        default={op: "xla_native" for op in registry.OPS})
    path = tmp_path / "policy.json"
    table.save(str(path))
    loaded = PolicyTable.load(str(path))
    assert loaded == table
    doc = json.loads(path.read_text())
    assert doc["version"] == 1 and doc["rules"][0]["algorithm"] == "tree"
    # load_policy installs it as the active table
    prev = registry.active_policy()
    try:
        active = jmpi.load_policy(str(path))
        assert registry.active_policy() is active
        assert registry.choose_name("bcast", 256, 8) == "tree"
    finally:
        registry.set_policy(prev)


def test_set_algorithm_validates_and_overrides():
    with pytest.raises(ValueError, match="no algorithm"):
        jmpi.set_algorithm("allreduce", "nope")
    with pytest.raises(ValueError, match="unknown collective op"):
        registry.register("not_an_op", "x")(lambda *a, **k: None)
    try:
        jmpi.set_algorithm("allreduce", "ring")
        assert registry.choose_name("allreduce", 1 << 20, 8) == "ring"
    finally:
        jmpi.clear_algorithms()
    assert registry.choose_name("allreduce", 1 << 20, 8) == "xla_native"
    with jmpi.algorithm_override(bcast="tree"):
        assert registry.choose_name("bcast", 1 << 20, 8) == "tree"
    assert registry.choose_name("bcast", 1 << 20, 8) == "xla_native"


def test_default_policy_is_size_aware():
    # built-in table: latency-bound payloads take the log-round schedules
    assert registry.choose_name("allreduce", 64, 8) == "recursive_doubling"
    assert registry.choose_name("allreduce", 1 << 20, 8) == "xla_native"
    assert registry.choose_name("bcast", 64, 8) == "tree"


def test_tuner_build_policy_from_records():
    from repro.launch.collective_tuner import build_policy, crossover_report

    records = [
        {"op": "allreduce", "algorithm": "xla_native", "numel": 64,
         "nbytes": 256, "ranks": 8, "us_per_call": 10.0},
        {"op": "allreduce", "algorithm": "recursive_doubling", "numel": 64,
         "nbytes": 256, "ranks": 8, "us_per_call": 5.0},
        {"op": "allreduce", "algorithm": "xla_native", "numel": 1024,
         "nbytes": 4096, "ranks": 8, "us_per_call": 12.0},
        {"op": "allreduce", "algorithm": "recursive_doubling", "numel": 1024,
         "nbytes": 4096, "ranks": 8, "us_per_call": 40.0},
    ]
    table = build_policy(records)
    # small regime: rd wins, bounded by the geometric midpoint edge
    assert table.choose("allreduce", 256, 8) == "recursive_doubling"
    assert table.choose("allreduce", 4096, 8) == "xla_native"
    report = crossover_report(records)
    assert "recursive_doubling" in report and "2.00x" in report


class _FakeComm:
    """select() only reads ``backend`` and ``size()`` on the explicit-name
    path, so the message pins below run host-side without a mesh."""

    backend = "emulated"

    def size(self):
        return 8


def test_compressed_lowerings_reject_non_float_payloads():
    """ISSUE 8 satellite pin: ``bf16_wire`` and both EF lowerings refuse
    integer/bool payloads (silent rounding through a quantized wire would
    corrupt them) with the registry's uniform trace-time message — exact
    text pinned here, backend-portable behavior in cases_compression."""
    comm = _FakeComm()
    for name in ("bf16_wire", "int8_ef", "topk_ef"):
        for bad in (jnp.zeros((8,), jnp.int32), jnp.zeros((8,), jnp.bool_)):
            with pytest.raises(
                    ValueError,
                    match=rf"algorithm '{name}' cannot handle this "
                          rf"allreduce call \(shape=\(8,\), "
                          rf"dtype={np.dtype(bad.dtype).name}"):
                registry.select("allreduce", bad, comm, algorithm=name)
        # float payloads select the named lowering
        algo = registry.select("allreduce", jnp.zeros((8,), jnp.float32),
                               comm, algorithm=name,
                               op=jmpi.Operator.SUM)
        assert algo.name == name


def test_compressed_lowerings_reject_non_sum_operators():
    """EF quantization only commutes with SUM — MAX/PROD must raise the
    uniform (algorithm, Operator) error, never silently mis-reduce."""
    comm = _FakeComm()
    x = jnp.zeros((8,), jnp.float32)
    for name in ("int8_ef", "topk_ef"):
        with pytest.raises(ValueError,
                           match=rf"algorithm '{name}' for 'allreduce' does "
                                 rf"not support Operator\.MAX"):
            registry.select("allreduce", x, comm, algorithm=name,
                            op=jmpi.Operator.MAX)


def test_wire_bytes_model_counts_topk_index_bytes():
    """Satellite-4 fix: the top-k wire model charges 8 bytes per kept entry
    (int32 index + fp32 value), with the k >= 1 floor."""
    comp, base = jmpi.wire_bytes_per_rank(4096, 8, topk_frac=1 / 64)
    assert comp == 7 * (4096 // 64) * (4 + 4)
    assert base == 2 * (7 / 8) * 4096 * 4
    tiny, _ = jmpi.wire_bytes_per_rank(16, 8, topk_frac=0.001)
    assert tiny == 7 * 1 * (4 + 4)


def test_param_sharder_collective_plan():
    from repro.distributed.params import ParamSharder
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(1, axes=("data",))
    sharder = ParamSharder(cfg=None, mesh=mesh)
    tree = {"w": jax.ShapeDtypeStruct((1024, 1024), jnp.float32),
            "b": jax.ShapeDtypeStruct((8,), jnp.float32)}
    plan = sharder.collective_plan(tree)
    assert plan["w"]["bytes"] == 1024 * 1024 * 4
    assert plan["b"]["bytes"] == 32
    assert plan["w"]["op"] == plan["b"]["op"] == "allreduce"
    # per-payload routing: the tiny leaf takes the latency algorithm under
    # the built-in policy, the big one stays native
    assert plan["b"]["algorithm"] == registry.choose_name("allreduce", 32, 1)
    assert plan["w"]["algorithm"] == registry.choose_name(
        "allreduce", 1024 * 1024 * 4, 1)
    # bucketed counterpart (ISSUE 5): ONE pytree datatype for the tree —
    # a single wire payload whose size is the sum of the leaves
    bucket = sharder.pytree_plan(tree)
    assert bucket["op"] == "allreduce" and bucket["datatype"] == "pytree"
    assert bucket["leaves"] == 2
    assert bucket["count"] == 1024 * 1024 + 8
    assert bucket["bytes"] == (1024 * 1024 + 8) * 4
    assert bucket["algorithm"] == registry.choose_name(
        "allreduce", bucket["bytes"], 1)


def test_view_scatter_into_truncation_semantics():
    base = jnp.full((3, 4), -1.0, jnp.float32)
    view = jmpi.View(base, (slice(0, 3), slice(0, 4)))
    # longer message: leading elements land, tail dropped
    msg = jnp.arange(20.0, dtype=jnp.float32)
    out = np.asarray(view.scatter_into(msg))
    np.testing.assert_array_equal(out.ravel(), np.arange(12.0))
    # shorter message: untouched slots keep prior contents
    out = np.asarray(view.scatter_into(jnp.arange(5.0, dtype=jnp.float32)))
    np.testing.assert_array_equal(out.ravel()[:5], np.arange(5.0))
    np.testing.assert_array_equal(out.ravel()[5:], -1.0)


def test_compat_shims_single_device():
    mesh = compat.make_mesh((1,), ("ranks",))
    from jax.sharding import PartitionSpec as P

    f = compat.shard_map(lambda x: x * 2, mesh, in_specs=P(), out_specs=P())
    np.testing.assert_array_equal(np.asarray(jax.jit(f)(jnp.ones(3))),
                                  2 * np.ones(3))


def test_property_testing_shim_reports_falsifying_example():
    from repro.testing import _Strategies, _shim_given, _shim_settings

    st = _Strategies

    @_shim_settings(max_examples=50)
    @_shim_given(x=st.integers(0, 100))
    def failing(x):
        assert x < 90, "too big"

    with pytest.raises(AssertionError, match="falsified"):
        failing()

    @_shim_settings(max_examples=10)
    @_shim_given(a=st.sampled_from([1, 2]), b=st.tuples(st.booleans()))
    def passing(a, b):
        assert a in (1, 2) and isinstance(b[0], bool)

    passing()
