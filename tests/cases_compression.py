"""Error-feedback compression oracle suite (ISSUE 8 satellite 1).

Backend-portable ``case_*`` functions for the stateful compressed-wire
lowerings (``int8_ef``, ``topk_ef``, ``repro.core.compression``): the
telescoping-identity oracle, residual-norm boundedness, bitwise
determinism, integer-payload rejection, bucket-overlap scheduling order,
and the wire-byte accounting (closed-form on emulated, the endpoint
``wire_stats()`` spy on multiproc).

Runs under the emulated mesh at any device count (``tests/
test_compression_multidev.py`` pins n ∈ {1, 2, 8}) AND under real
multi-process jobs via the parity suite (``tests/test_parity_multiproc.py``
at {sock, shm} × {2, 4}) — ``N`` is derived from the environment, never
hardcoded.

The telescoping identity (the EF correctness anchor): with a fixed per-rank
gradient g_r and e_{r,0} = 0, every lowering satisfies

    sum_t out_t  =  T · sum_r g_r  −  sum_r e_{r,T}   (+ second-stage error)

because each step transmits (g_r + e_{r,t-1}) − e_{r,t} exactly — for
``topk_ef`` exactly (fp32 values ride the wire), for ``int8_ef`` up to the
post-sum requantization of the gather phase, which is shared across ranks,
NOT fed back, and bounded by T·n·amax/254 per element (the derived
tolerance below).
"""

from __future__ import annotations

import os

import jax

jax.config.update("jax_enable_x64", True)  # match cases_core (parity module)

import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

import repro.core as jmpi
from repro.core import compat
from repro.core.compression import EF_ALGORITHMS

# Same environment contract as cases_core, but N follows the actual world
# size on BOTH backends: the launcher's JMPI_NP under multiproc, the
# emulated device count (--xla_force_host_platform_device_count) otherwise —
# this module must hold at n ∈ {1, 2, 8}, so nothing may assume n == 8.
_BACKEND = os.environ.get("JMPI_BACKEND", "emulated")
N = (int(os.environ["JMPI_NP"]) if _BACKEND == "multiproc"
     else len(jax.devices()))


def mesh1d():
    return compat.make_mesh((N,), ("ranks",))


def spmd_collective(fn, shards):
    """Run fn(rank_local_block) on every rank; return per-rank results."""
    if _BACKEND == "multiproc":
        from repro.transport.testing import run_collective
        return run_collective(fn, shards)
    mesh = mesh1d()

    @jmpi.spmd(mesh, in_specs=P("ranks"), out_specs=P("ranks"))
    def run(x):
        y = fn(x[0])
        return y[None]

    glob = jnp.stack(shards)
    return [np.asarray(run(glob)[i]) for i in range(N)]


def rand(shape, dtype, seed=0):
    rng = np.random.default_rng(seed)
    return np.asarray(jnp.asarray(rng.standard_normal(shape), dtype=dtype))


# ---------------------------------------------------------------------- #
# (a) telescoping-identity oracle
# ---------------------------------------------------------------------- #

_ORACLE_T = 3          # EF steps per grid point
_ORACLE_NUMEL = 64     # divisible by every world size in {1, 2, 4, 8}


def _oracle_run(op, algo, dtype, shards):
    """T compressed steps on a fixed gradient; per rank, return
    concat(sum of outputs, final residual)."""

    def run(g):
        comm = jmpi.world()
        st = jmpi.init_state(g)
        acc = None
        for _ in range(_ORACLE_T):
            if op == "allreduce":
                status, out, st = jmpi.compressed_allreduce(
                    g, st, comm=comm, algorithm=algo, mean=False)
            else:
                status, out, st = jmpi.compressed_reduce_scatter(
                    g, st, comm=comm, algorithm=algo, mean=False)
            assert status == jmpi.SUCCESS
            out32 = out.astype(jnp.float32).reshape(-1)
            acc = out32 if acc is None else acc + out32
        return jnp.concatenate([acc, st.error.astype(jnp.float32).reshape(-1)])

    return spmd_collective(run, shards)


def case_ef_telescoping_identity_grid():
    """sum_t out_t + sum_r e_{r,T} == T·(exact fp32 sum), per lowering ×
    collective × dtype, within the derived second-stage tolerance."""
    for algo in EF_ALGORITHMS:
        for op in ("allreduce", "reduce_scatter"):
            for dtype in (jnp.float32, jnp.float64):
                shards = [rand((_ORACLE_NUMEL,), dtype, seed=10 + r)
                          for r in range(N)]
                exact = np.sum(np.stack([s.astype(np.float64)
                                         for s in shards]), axis=0)
                amax = max(float(np.max(np.abs(s))) for s in shards)
                got = _oracle_run(op, algo, dtype, shards)

                chunk = (_ORACLE_NUMEL if op == "allreduce"
                         else _ORACLE_NUMEL // N)
                errs = np.stack([np.asarray(r)[chunk:] for r in got])
                err_sum = errs.sum(axis=0)
                expected = _ORACLE_T * exact - err_sum

                if algo == "int8_ef":
                    # post-sum requantization: <= amax(acc)/254 per element
                    # per step, amax(acc) <= n·amax(g+e); factor 2 headroom.
                    atol = _ORACLE_T * N * amax / 127.0
                else:
                    atol = 1e-4 * _ORACLE_T * max(amax, 1.0)  # fp ordering

                for r, res in enumerate(got):
                    acc = np.asarray(res)[:chunk]
                    want = (expected if op == "allreduce"
                            else expected[r * chunk:(r + 1) * chunk])
                    np.testing.assert_allclose(
                        acc, want, atol=atol, rtol=0,
                        err_msg=f"{algo}/{op}/{np.dtype(dtype)} rank {r}")


# ---------------------------------------------------------------------- #
# (b) residual-norm boundedness on a fixed gradient
# ---------------------------------------------------------------------- #

def _norm_run(algo, steps, shards):
    def run(g):
        comm = jmpi.world()
        st = jmpi.init_state(g)
        norms = []
        for _ in range(steps):
            _, _, st = jmpi.compressed_allreduce(g, st, comm=comm,
                                                 algorithm=algo, mean=True)
            norms.append(jnp.linalg.norm(st.error))
        return jnp.stack(norms)
    return [np.asarray(r) for r in spmd_collective(run, shards)]


def case_ef_residual_norm_bounded():
    """Residual norms on a fixed gradient stay at/below their initial level.

    Honest form of the "non-increasing" property — the strict per-step
    statement is FALSE for both lowerings, so this case pins what actually
    holds (measured in EXPERIMENTS-style sweeps before pinning):

    * ``int8_ef``: e_t is the quantization error of g + e_{t-1}; its norm
      sits at the quantization floor sqrt(numel)·amax/254 from step 0 and
      fluctuates ±~25% (independent rounding noise), without trend.  Pinned:
      floor bound, no-upward-trend, and <= 2% of ||g||.
    * ``topk_ef``: untransmitted coordinates accumulate t·|g_i| until they
      cross the top-k threshold and flush, so the norm RISES from ||e_1||
      toward a plateau (~3.5·||g|| at frac=0.125) — pinned: bounded plateau
      (<= 5·||g||) and decelerating growth.
    """
    shards = [rand((_ORACLE_NUMEL,), jnp.float32, seed=20 + r)
              for r in range(N)]
    amax = max(float(np.max(np.abs(s))) for s in shards)
    gnorm = [float(np.linalg.norm(s)) for s in shards]

    # int8: quantization-floor bound + no upward trend
    for r, norms in enumerate(_norm_run("int8_ef", 10, shards)):
        floor = np.sqrt(_ORACLE_NUMEL) * amax * 1.05 / 254.0
        assert norms.max() <= floor + 1e-6, (r, norms, floor)
        assert norms.max() <= 0.02 * gnorm[r], (r, norms, gnorm[r])
        assert norms[5:].mean() <= 1.2 * norms[:5].mean(), (r, norms)

    # topk: bounded plateau + decelerating accumulate-then-flush growth
    for r, norms in enumerate(_norm_run("topk_ef", 12, shards)):
        assert norms.max() <= 5.0 * gnorm[r] + 1.0, (r, norms, gnorm[r])
        early = norms[2] - norms[0]
        late = norms[11] - norms[9]
        assert late <= 0.5 * early + 0.05 * gnorm[r], (r, norms)


# ---------------------------------------------------------------------- #
# (c) bitwise determinism
# ---------------------------------------------------------------------- #

def case_ef_determinism_bitwise():
    """Two identical compressed runs produce bit-identical outputs AND
    residuals on every rank, for both lowerings (deterministic top-k
    tie-break, rank-order combines on the wire backend)."""
    for algo in EF_ALGORITHMS:
        shards = [rand((_ORACLE_NUMEL,), jnp.float32, seed=30 + r)
                  for r in range(N)]
        a = _oracle_run("allreduce", algo, jnp.float32, shards)
        b = _oracle_run("allreduce", algo, jnp.float32, shards)
        for r in range(N):
            assert np.array_equal(np.asarray(a[r]), np.asarray(b[r])), (
                f"{algo}: rank {r} differs between identical runs")


# ---------------------------------------------------------------------- #
# trace-time rejection of non-float payloads
# ---------------------------------------------------------------------- #

def case_compressed_rejects_integer_payloads():
    """Quantizing an int payload would silently corrupt it: an explicit
    ``algorithm="int8_ef"/"topk_ef"`` on int32 raises the registry's
    uniform trace-time ValueError (same message shape as every other
    lowering mismatch; exact text pinned host-side in test_registry.py)."""
    src = [np.arange(8, dtype=np.int32) + r for r in range(N)]
    for algo in EF_ALGORITHMS:
        def bad(x, algo=algo):
            _, y = jmpi.allreduce(x, algorithm=algo)
            return y

        try:
            spmd_collective(bad, src)
        except Exception as e:
            msg = str(e)
            assert "cannot handle this allreduce call" in msg, msg
            assert algo in msg, msg
        else:
            raise AssertionError(f"{algo} accepted an int32 payload")

    # the stateful front-end rejects unknown lowerings before any traffic
    z = jnp.zeros((4,), jnp.float32)
    try:
        jmpi.icompressed_allreduce(z, jmpi.init_state(z), algorithm="gzip")
    except ValueError as e:
        assert "stateful compression requires" in str(e)
    else:
        raise AssertionError("unknown algorithm accepted")


# ---------------------------------------------------------------------- #
# bucketed sync: overlap scheduling order + bitwise serial equivalence
# ---------------------------------------------------------------------- #

_OVL_SHAPES = ((40,), (24,), (8, 2))


def _ovl_split(flat):
    out, o = [], 0
    for s in _OVL_SHAPES:
        n = int(np.prod(s))
        out.append(flat[o:o + n].reshape(s))
        o += n
    return out


def case_bucketed_overlap_ordering():
    """``overlap=True`` issues EVERY bucket's iallreduce before the single
    waitall (the issue-early/complete-late window the trainer hides backward
    compute in); ``overlap=False`` waits per bucket.  Both schedules chain
    the same collectives over the same payloads, so their reduced gradients
    AND residuals are bitwise identical — for fp32 plan buckets and for both
    compressed lowerings."""
    from repro.distributed import overlap as overlap_lib

    total = sum(int(np.prod(s)) for s in _OVL_SHAPES)
    shards = [rand((total,), jnp.float32, seed=40 + r) for r in range(N)]

    for algo in ("",) + EF_ALGORITHMS:
        logs = {}

        def make(overlap, log):
            def run(flat):
                comm = jmpi.world()
                grads = _ovl_split(flat)
                comp = [jmpi.init_state(g) for g in grads]
                red, newc = overlap_lib.bucketed_grad_sync(
                    grads, comp, comm=comm, algorithm=algo, buckets=2,
                    overlap=overlap, mean=True, trace_log=log)
                parts = [r.reshape(-1) for r in red]
                if algo:
                    parts += [c.error.reshape(-1) for c in newc]
                return jnp.concatenate(parts)
            return run

        logs["serial"], logs["overlap"] = [], []
        serial = spmd_collective(make(False, logs["serial"]), shards)
        over = spmd_collective(make(True, logs["overlap"]), shards)

        # scheduling order (captured at trace time / eager execution):
        # serial interleaves issue/wait; overlap ends with one waitall.
        n_issue = sum(1 for ev in logs["overlap"] if ev[0] == "issue")
        assert logs["overlap"][-1] == ("waitall",), logs["overlap"]
        assert logs["overlap"][:-1] == [("issue", b) for b in range(n_issue)]
        assert logs["serial"] == [ev for b in range(n_issue)
                                  for ev in (("issue", b), ("wait", b))]

        for r in range(N):
            assert np.array_equal(np.asarray(serial[r]), np.asarray(over[r])), \
                f"algorithm={algo!r}: rank {r} serial != overlap"


# ---------------------------------------------------------------------- #
# wire bytes: measured on multiproc, closed-form on emulated
# ---------------------------------------------------------------------- #

def case_wire_bytes_compressed():
    """Compressed frames are literally smaller on the wire.

    Multiproc: bracket collectives with the endpoint's transmit spy
    (``reset_wire_stats``/``wire_stats``) — int8 payload bytes must be
    <= 26% of the fp32 direct baseline ((numel+4)/(4·numel) ≈ 25%), top-k
    at frac=1/32 <= 10% (measured ≈ 6.25%).

    Emulated: no real wire, so pin the closed-form ``wire_bytes_per_rank``
    model instead — including that top-k counts its int32 INDEX bytes
    (satellite-4 fix), and that the two-phase int8 model is N-aware (ratio
    1/2 at n=2, 2/7 at n=8 — the ≈25% figure belongs to the single-phase
    direct kernel measured above)."""
    numel = 16384
    if _BACKEND == "multiproc":
        from repro.core import comm as comm_lib
        from repro.core import token as token_lib

        comm = comm_lib.world()
        ep, n = comm.endpoint, comm.size()
        g = jnp.asarray(rand((numel,), jnp.float32, seed=3))
        token_lib.reset_ambient()
        ep.barrier()

        ep.reset_wire_stats()
        jmpi.allreduce(g, comm=comm)
        base = ep.wire_stats()["data_bytes"]
        assert base == (n - 1) * 4 * numel, (base, n)

        ep.reset_wire_stats()
        jmpi.compressed_allreduce(g, jmpi.init_state(g), comm=comm,
                                  algorithm="int8_ef")
        int8_bytes = ep.wire_stats()["data_bytes"]
        assert int8_bytes <= 0.26 * base, (int8_bytes, base)

        ep.reset_wire_stats()
        jmpi.compressed_allreduce(g, jmpi.init_state(g), comm=comm,
                                  algorithm="topk_ef", frac=1 / 32)
        topk_bytes = ep.wire_stats()["data_bytes"]
        assert topk_bytes <= 0.10 * base, (topk_bytes, base)
    else:
        comp8, base8 = jmpi.wire_bytes_per_rank(numel, 8)
        assert comp8 == 2 * numel
        assert base8 == 2 * (7 / 8) * numel * 4
        assert comp8 / base8 <= 0.30

        comp16, _ = jmpi.wire_bytes_per_rank(numel, 8, bits=16)
        assert comp16 == 2 * (7 / 8) * numel * 2

        # topk model: (n−1)·k·(idx 4B + val 4B) vs the RING fp32 baseline,
        # i.e. ratio = frac·n — the ≈6% figure belongs to the direct-kernel
        # measurement above, whose fp32 baseline is n/2× the ring's.
        k = numel // 32
        compk, _ = jmpi.wire_bytes_per_rank(numel, 8, topk_frac=1 / 32)
        assert compk == 7 * k * (4 + 4)      # index bytes are counted
        assert compk / base8 == (1 / 32) * 8

        comp2, base2 = jmpi.wire_bytes_per_rank(numel, 2)
        assert comp2 / base2 == 0.5          # two-phase model at n=2
