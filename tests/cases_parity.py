"""Backend-parity subset: the oracle cases that are meaningful on BOTH
backends (emulated shard_map mesh and real multiproc transport).

The case *functions* are re-exported unmodified from ``cases_core`` /
``cases_datatypes`` — the whole point is that one oracle body validates
both lowerings.  Membership is conditioned on the world size ``N`` (which
the source modules derive from ``JMPI_NP`` under multiproc): e.g. the
tag-matching case posts receives from ranks 2 and 3, and the topology
error case needs the out-of-range probe to be distinguishable from the
injectivity probe, so both join only at N >= 4.

The compressed-wire lowerings joined the parity set with ISSUE 8: the
error-feedback oracle suite (``cases_compression``) derives N from the
environment and its multiproc run is what exercises the native ``direct``
int8/top-k kernels — including the measured wire-byte reduction.

Excluded on purpose (not N-portable): subcommunicator/multiaxis cases
(need a 2-D mesh), ring-schedule cases (emulated-only algorithm studies),
and cases whose pair schedules hardcode ranks >= 4.
"""

from __future__ import annotations

from tests.cases_core import (  # noqa: F401 — re-exported for the case runner
    N,
    case_allreduce_logical,
    case_allreduce_operators,
    case_alltoall_reduce_scatter,
    case_barrier_and_token_sequencing,
    case_disable_jit_debug_mode,
    case_listing5_exchange,
    case_p2p_err_truncate,
    case_property_collectives_match_oracle,
    case_property_permute_roundtrip,
    case_scatter_gather_allgather,
    case_sendrecv_ring_all_dtypes,
    case_view_strided_send_recv,
    case_wtime,
)
from tests.cases_compression import (  # noqa: F401
    case_bucketed_overlap_ordering,
    case_compressed_rejects_integer_payloads,
    case_ef_determinism_bitwise,
    case_ef_residual_norm_bounded,
    case_ef_telescoping_identity_grid,
    case_wire_bytes_compressed,
)
from tests.cases_datatypes import (  # noqa: F401
    case_err_truncate_three_paths,
    case_p2p_datatype_payloads,
    case_vvariant_requests_and_plans,
    case_vvariant_validation_errors,
)

if N >= 4:
    from tests.cases_core import (  # noqa: F401
        case_bcast_all_dtypes,
        case_p2p_tag_matching,
        case_p2p_trace_time_topology_errors,
        case_view_transposed_fortran_analogue,
    )
