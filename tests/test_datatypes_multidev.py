"""Pytest wrappers for the derived-datatype + v-variant collective cases
(datatype algebra round-trips, scatterv/gatherv/allgatherv/alltoallv vs
the numpy oracle under every lowering, i*/_init surfaces, (payload,
datatype) uniformity on p2p, ERR_TRUNCATE across all three paths).

Acceptance (ISSUE 5): every case passes for n ∈ {1, 2, 8} ranks.  The case
module is device-count agnostic; each count runs it once in its own child
process (cached transcript).  The 8-rank run is marked slow (quick lane
covers 1 and 2 ranks), mirroring tests/test_plans_multidev.py.
"""

import pytest

from repro.testing import assert_case

pytestmark = pytest.mark.multidev

CASES = [
    "case_datatype_algebra_roundtrips",
    "case_datatype_protocol_guards",
    "case_view_index_errors_and_negative_steps",
    "case_scatterv_matches_oracle_all_algorithms",
    "case_gatherv_allgatherv_match_oracle_all_algorithms",
    "case_alltoallv_matches_oracle_all_algorithms",
    "case_alltoallv_multiaxis_comm_default_policy",
    "case_vvariant_requests_and_plans",
    "case_vvariant_validation_errors",
    "case_p2p_datatype_payloads",
    "case_collective_datatype_payloads",
    "case_err_truncate_three_paths",
    "case_face_datatypes_match_manual_slices",
]

N_RANKS = [1, 2, pytest.param(8, marks=pytest.mark.slow)]


@pytest.mark.parametrize("n", N_RANKS)
@pytest.mark.parametrize("case", CASES)
def test_datatypes_case(case, n):
    assert_case("tests.cases_datatypes", case, n_devices=n)
