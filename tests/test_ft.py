"""Fault-tolerance tests: checkpoint/restart replay, failure injection,
straggler watchdog, deterministic data pipeline, elastic mesh resume."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_tiny
from repro.configs.base import RunConfig, ShapeCell
from repro.launch.mesh import make_host_mesh
from repro.models import lm as lm_lib
from repro.train import checkpoint as ckpt
from repro.train import optim
from repro.train.data import SyntheticLM
from repro.train.ft import FailureInjector, Watchdog, run_with_restarts
from repro.train.trainer import build_train_step


def _setup(tmp, cfg=None):
    cfg = cfg or get_tiny("yi-6b")
    cfg.dtype = "float32"
    mesh = make_host_mesh(1, axes=("data",))
    cell = ShapeCell("t", 32, 4, "train")
    rc = RunConfig(learning_rate=1e-3)
    bundle = build_train_step(cfg, rc, mesh, cell)
    step = bundle.jitted()
    data = SyntheticLM(cfg, 4, 32)

    def data_fn(i):
        return {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}

    def init_state():
        params = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
        return (params, optim.init(params, rc))

    def step_fn(state, batch):
        p, o, m = step(state[0], state[1], batch)
        return (p, o), {"loss": float(m["loss"])}

    return step_fn, data_fn, init_state


def test_data_pipeline_deterministic_and_sharded():
    cfg = get_tiny("yi-6b")
    d = SyntheticLM(cfg, global_batch=8, seq_len=32)
    a = d.batch_at(7)
    b = d.batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # shards partition the global batch
    full = d.batch_at(3)["tokens"]
    parts = [d.batch_at(3, shard=i, n_shards=4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)
    # labels are next-token targets of a learnable sequence
    assert a["labels"].shape == a["tokens"].shape


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_tiny("yi-6b")
    params = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
    ckpt.save(str(tmp_path), params, step=41)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), params)
    restored, step, _ = ckpt.restore(str(tmp_path), like)
    assert step == 41
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_crash_restart_replays_identically(tmp_path):
    """Run A: 12 steps with a crash at step 7 (auto-restart).
    Run B: 12 steps, no crash.  Loss trajectories must match exactly
    (deterministic data replay + checkpoint restore)."""
    step_fn, data_fn, init_state = _setup(tmp_path)

    dir_a = os.path.join(str(tmp_path), "a")
    _, hist_a, restarts = run_with_restarts(
        lambda: (step_fn, data_fn), init_state, n_steps=12, ckpt_dir=dir_a,
        ckpt_every=5, injector=FailureInjector(fail_at=(7,)))
    assert restarts == 1

    dir_b = os.path.join(str(tmp_path), "b")
    _, hist_b, _ = run_with_restarts(
        lambda: (step_fn, data_fn), init_state, n_steps=12, ckpt_dir=dir_b,
        ckpt_every=5)

    # compare the last few steps (post-restart must agree with no-crash run)
    tail_a = {s: m["loss"] for s, m in hist_a}
    tail_b = {s: m["loss"] for s, m in hist_b}
    for s in range(8, 12):
        np.testing.assert_allclose(tail_a[s], tail_b[s], rtol=1e-6,
                                   err_msg=f"step {s} diverged after restart")


def test_watchdog_flags_stragglers():
    wd = Watchdog(threshold=2.0)
    for i in range(10):
        assert not wd.observe(i, 0.10 + 0.001 * i)
    assert wd.observe(10, 0.5)          # 5x median -> straggler
    assert len(wd.stragglers) == 1


def test_elastic_reshard_across_meshes(tmp_path):
    """Save on a 1-device mesh, restore onto a 4-emulated-device DP mesh in a
    child process (device counts are process-global) — elastic resume."""
    cfg = get_tiny("yi-6b")
    params = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
    ckpt.save(str(tmp_path), params, step=5)

    import subprocess
    import sys

    from repro.testing import child_env

    code = f"""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_tiny
from repro.models import lm as lm_lib
from repro.train import checkpoint as ckpt
from repro.launch.mesh import make_host_mesh
assert len(jax.devices()) == 4
cfg = get_tiny("yi-6b")
like = jax.eval_shape(lambda: lm_lib.init_params(cfg, jax.random.PRNGKey(0)))
like = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), like)
mesh = make_host_mesh(4, axes=("data",))
sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), like)
restored, step, _ = ckpt.restore({str(tmp_path)!r}, like, shardings=sh)
assert step == 5
leaf = jax.tree.leaves(restored)[0]
assert len(leaf.sharding.device_set) == 4
print("ELASTIC_OK")
"""
    proc = subprocess.run([sys.executable, "-c", code], env=child_env(4),
                          capture_output=True, text=True, timeout=600)
    assert "ELASTIC_OK" in proc.stdout, proc.stdout + proc.stderr


def test_async_saver_overlaps(tmp_path):
    cfg = get_tiny("yi-6b")
    params = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
    s = ckpt.AsyncSaver()
    s.save_async(str(tmp_path), params, 3)
    s.wait()
    assert ckpt.latest_step(str(tmp_path)) == 3
