"""Pytest wrappers for the jmpi 2.0 cases (nonblocking collectives,
persistent plans, communicator methods, unified Request completion).

Acceptance: every case passes for n ∈ {1, 2, 8} ranks.  The case module is
device-count agnostic; each count runs it once in its own child process
(cached transcript).  The 8-rank run is marked slow (quick lane covers
1 and 2 ranks), mirroring tests/test_registry_multidev.py.
"""

import pytest

from repro.testing import assert_case

pytestmark = pytest.mark.multidev

CASES = [
    "case_icollectives_match_oracle",
    "case_communicator_method_surface",
    "case_mixed_waitall_p2p_and_collective",
    "case_testall_waitall_tag_validation",
    "case_plans_match_oracle",
    "case_plan_cache_hits_and_shape_misses",
    "case_plan_freezes_algorithm_choice",
    "case_ring_all_operators_match_oracle",
    "case_unsupported_operator_uniform_error",
    "case_registry_operator_declarations",
]

N_RANKS = [1, 2, pytest.param(8, marks=pytest.mark.slow)]


@pytest.mark.parametrize("n", N_RANKS)
@pytest.mark.parametrize("case", CASES)
def test_plans_case(case, n):
    assert_case("tests.cases_plans", case, n_devices=n)
