"""Pytest wrappers for the collective-algorithm registry cases.

Acceptance: every registry algorithm passes the oracle property tests for
n ∈ {1, 2, 8} ranks.  The case module is device-count agnostic; each count
runs it once in its own child process (cached transcript).  The 8-rank run
compiles the full algorithm × operator × dtype matrix and is marked slow
(quick lane covers 1 and 2 ranks).
"""

import pytest

from repro.testing import assert_case

pytestmark = pytest.mark.multidev

CASES = [
    "case_allreduce_all_algorithms_match_oracle",
    "case_bcast_allgather_rs_alltoall_algorithms_match_oracle",
    "case_view_payloads_all_allreduce_algorithms",
    "case_property_all_algorithms_match_default",
    "case_override_changes_lowering",
    "case_policy_table_routes_by_size",
]

N_RANKS = [1, 2, pytest.param(8, marks=pytest.mark.slow)]


@pytest.mark.parametrize("n", N_RANKS)
@pytest.mark.parametrize("case", CASES)
def test_registry_case(case, n):
    assert_case("tests.cases_registry", case, n_devices=n)
