"""Derived-datatype layer + v-variant collective cases — device-count
agnostic (run under 1, 2 and 8 emulated devices via
tests/test_datatypes_multidev.py, reusing the cases_registry machinery).

Covers the ISSUE-5 tentpole: the datatype algebra round-trips
(contiguous/vector/subarray/indexed/Slots/pytree) host-side, every
v-variant lowering matches the numpy oracle, the i*/_init surfaces
complete through the unified Request/Plan model, p2p accepts
``(payload, datatype)`` uniformly, and the ERR_TRUNCATE satellite runs on
strided/ragged ``recv_into`` across all three paths (blocking,
irecv+wait, persistent plan).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as jmpi
from repro.core import datatypes as dt
from repro.core import ref
from tests.cases_registry import (N, _tol, rand, spmd_collective)

COUNTS = tuple((r % 3) + (1 if N <= 2 else 0) for r in range(N))
# guarantee at least one nonzero and ragged variation at every N
if sum(COUNTS) == 0:
    COUNTS = (1,) + COUNTS[1:]
MATRIX = tuple(tuple(((s + d) % 3) + (1 if N == 1 else 0) for d in range(N))
               for s in range(N))


def _sds(x):
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


# ---------------------------------------------------------------------- #
# host-side algebra round-trips (no devices needed; run in-child anyway)
# ---------------------------------------------------------------------- #

def case_datatype_algebra_roundtrips():
    rng = np.random.default_rng(0)
    buf = jnp.asarray(rng.standard_normal(24), jnp.float32)

    c = dt.contiguous(24)
    np.testing.assert_array_equal(np.asarray(c.pack(buf)), np.asarray(buf))
    np.testing.assert_array_equal(np.asarray(c.unpack(c.pack(buf))),
                                  np.asarray(buf))

    v = dt.vector(4, 2, 6)
    want = np.asarray(buf).reshape(4, 6)[:, :2].reshape(-1)
    np.testing.assert_array_equal(np.asarray(v.pack(buf)), want)
    restored = v.unpack(v.pack(buf), into=jnp.zeros_like(buf))
    back = np.zeros(24, np.float32)
    back.reshape(4, 6)[:, :2] = want.reshape(4, 2)
    np.testing.assert_array_equal(np.asarray(restored), back)

    x = jnp.asarray(rng.standard_normal((6, 5)), jnp.float32)
    sa = dt.subarray((6, 5), (2, 3), (1, 2))
    np.testing.assert_array_equal(np.asarray(sa.pack(x)),
                                  np.asarray(x)[1:3, 2:5])
    y = sa.unpack(jnp.zeros((2, 3)), into=x)
    w = np.asarray(x).copy()
    w[1:3, 2:5] = 0
    np.testing.assert_array_equal(np.asarray(y), w)

    ix = dt.indexed([2, 1, 3], [0, 4, 9])
    np.testing.assert_array_equal(np.asarray(ix.pack(jnp.arange(12.0))),
                                  [0, 1, 4, 9, 10, 11])

    sl = dt.slots([(2, 2), (3,)], jnp.float32)
    slots_in = [jnp.ones((2, 2)), jnp.arange(3.0)]
    flat = sl.pack(slots_in)
    assert flat.shape == (7,)
    back_slots = sl.unpack(flat)
    np.testing.assert_array_equal(np.asarray(back_slots[1]), [0, 1, 2])

    tree = {"w": jnp.ones((2, 3), jnp.bfloat16),
            "b": jnp.arange(4, dtype=jnp.int32)}
    pd = dt.pytree(tree, wire_dtype=jnp.float32)
    vec = pd.pack(tree)
    assert vec.shape == (10,) and vec.dtype == jnp.float32
    tree2 = pd.unpack(vec)
    assert tree2["w"].dtype == jnp.bfloat16 and tree2["b"].dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(tree2["b"]), np.arange(4))


def case_datatype_protocol_guards():
    """Pytree.pack rejects same-structure/different-key trees (a silent
    relabel would mis-associate gradients); fully-covering datatypes work
    as recv adapters through bind(None); sparse datatypes passed unbound
    as recv targets raise the clear bind-first TypeError."""
    pd = dt.pytree({"a": jnp.ones(2), "b": jnp.ones(3)})
    try:
        pd.pack({"a": jnp.ones(2), "c": jnp.full(3, 9.0)})
    except ValueError as e:
        assert "frozen for" in str(e)
    else:
        raise AssertionError("pytree.pack must reject mismatched keys")

    sl = dt.slots([(2,), (3,)], jnp.float32)
    bound = dt.recv_adapter(sl)           # fully covering: auto-bound
    out = bound.scatter_into(jnp.arange(5.0))
    np.testing.assert_array_equal(np.asarray(out[1]), [2, 3, 4])
    tb = dt.recv_adapter(pd)
    tree = tb.scatter_into(jnp.arange(5.0))
    np.testing.assert_array_equal(np.asarray(tree["b"]), [2, 3, 4])

    for sparse in (dt.vector(2, 1, 2), dt.subarray((4,), (2,), (1,)),
                   dt.indexed([1], [0]), dt.contiguous(4)):
        try:
            dt.recv_adapter(sparse)
        except TypeError as e:
            assert "bind it to a buffer" in str(e)
        else:
            raise AssertionError(f"unbound {type(sparse).__name__} must be "
                                 f"rejected as a recv target")


def case_view_index_errors_and_negative_steps():
    """Satellite: Ellipsis/None/array indices raise a clear TypeError;
    negative-step slices pack/unpack correctly."""
    x = jnp.arange(36.0).reshape(6, 6)
    for bad in [(Ellipsis,), (None,), (np.array([0, 1]),), ([0, 1],),
                (slice(0, 2), Ellipsis)]:
        try:
            jmpi.View(x, bad)
        except TypeError as e:
            msg = str(e)
            assert ("Ellipsis" in msg or "newaxis" in msg or "fancy" in msg
                    or "slice/int" in msg), msg
        else:
            raise AssertionError(f"expected TypeError for index {bad!r}")

    v = jmpi.View(x, (slice(None, None, -1), slice(4, 0, -2)))
    np.testing.assert_array_equal(np.asarray(v.pack()),
                                  np.asarray(x)[::-1, 4:0:-2])
    y = v.unpack(jnp.zeros((6, 2)))
    w = np.asarray(x).copy()
    w[::-1, 4:0:-2] = 0
    np.testing.assert_array_equal(np.asarray(y), w)
    # negative int index squeezes the dim
    v2 = jmpi.View(x, (-2,))
    np.testing.assert_array_equal(np.asarray(v2.pack()), np.asarray(x)[-2])


# ---------------------------------------------------------------------- #
# v-variants vs numpy oracle, every lowering, blocking + i* + plans
# ---------------------------------------------------------------------- #

def case_scatterv_matches_oracle_all_algorithms():
    total = sum(COUNTS)
    full = rand((max(total, 1), 3), jnp.float32, seed=7)
    np_full = np.asarray(full)[:total]
    want = ref.scatterv([np_full] * N, COUNTS, root=0)
    for algo in ("xla_native", "linear"):
        got = spmd_collective(
            lambda x, a=algo: jmpi.scatterv(
                jnp.asarray(np_full), COUNTS, root=0, algorithm=a)[1],
            [rand((1,), jnp.float32, seed=i) for i in range(N)])
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, **_tol(jnp.float32, algo, ""),
                                       err_msg=f"scatterv {algo}")


def case_gatherv_allgatherv_match_oracle_all_algorithms():
    maxc = max(COUNTS)
    src = []
    for r in range(N):
        buf = np.zeros((max(maxc, 1), 2), np.float32)
        buf[:COUNTS[r]] = 100 * r + np.arange(COUNTS[r] * 2).reshape(-1, 2)
        src.append(jnp.asarray(buf[:maxc] if maxc else buf[:0]))
    np_src = [np.asarray(s) for s in src]
    want = ref.allgatherv(np_src, COUNTS)
    for algo in ("xla_native", "ring"):
        got = spmd_collective(
            lambda x, a=algo: jmpi.allgatherv(x, COUNTS, algorithm=a)[1], src)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, err_msg=f"allgatherv {algo}")
        got = spmd_collective(
            lambda x, a=algo: jmpi.gatherv(x, COUNTS, root=0,
                                           algorithm=a)[1], src)
        np.testing.assert_allclose(got[0], ref.gatherv(np_src, COUNTS)[0],
                                   err_msg=f"gatherv {algo}")


def case_alltoallv_matches_oracle_all_algorithms():
    maxc = max(c for row in MATRIX for c in row)
    src = []
    for s in range(N):
        buf = np.zeros((N, max(maxc, 1), 2), np.float32)
        for d in range(N):
            c = MATRIX[s][d]
            buf[d, :c] = 1000 * s + 10 * d + np.arange(c * 2).reshape(-1, 2)
        src.append(jnp.asarray(buf[:, :maxc] if maxc else buf[:, :0]))
    np_src = [np.asarray(s) for s in src]
    want = ref.alltoallv(np_src, MATRIX)
    for algo in ("xla_native", "pairwise"):
        got = spmd_collective(
            lambda x, a=algo: jmpi.alltoallv(x, MATRIX, algorithm=a)[1], src)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, err_msg=f"alltoallv {algo}")


def case_alltoallv_multiaxis_comm_default_policy():
    """Regression: on a multi-axis communicator the default (policy)
    selection must NOT execute the single-axis xla_native all_to_all —
    the registry's fallback scan routes to the pairwise schedule and the
    result matches the oracle."""
    if N < 4:
        return
    import jax as _jax
    from jax.sharding import PartitionSpec as P
    from repro.core import compat
    mesh = compat.make_mesh((2, N // 2), ("a", "b"))
    counts = tuple(tuple(((s + d) % 2) + 1 for d in range(N))
                   for s in range(N))
    maxc = 2
    src = []
    for s in range(N):
        buf = np.zeros((N, maxc, 2), np.float32)
        for d in range(N):
            c = counts[s][d]
            buf[d, :c] = 100 * s + 10 * d + np.arange(c * 2).reshape(-1, 2)
        src.append(buf)
    want = ref.alltoallv(src, counts)

    @jmpi.spmd(mesh, in_specs=P(("a", "b")), out_specs=P(("a", "b")))
    def run(x):
        _, out = jmpi.alltoallv(x[0], counts)   # default algorithm choice
        return out[None]

    out = run(jnp.asarray(np.stack(src)))
    for r in range(N):
        np.testing.assert_allclose(np.asarray(out[r]), want[r],
                                   err_msg=f"rank {r}")


def case_vvariant_requests_and_plans():
    """i* forms return unified Requests (mixed waitall with p2p); *_init
    plans freeze the algorithm, cache on the signature, and reject
    mismatched starts."""
    jmpi.plan_cache_clear()
    maxc = max(COUNTS)
    src = [rand((max(maxc, 1), 2), jnp.float32, seed=20 + i)
           for i in range(N)]
    src = [s[:maxc] for s in src]
    np_src = [np.asarray(s) for s in src]
    want = ref.allgatherv(np_src, COUNTS)

    def f(x):
        comm = jmpi.world()
        r1 = comm.iallgatherv(x, COUNTS, tag=6)
        r2 = comm.isendrecv(x, pairs=comm.ring_perm(1), tag=6)
        status, [stacked, shifted] = jmpi.waitall([r1, r2], tag=6)
        assert status == jmpi.SUCCESS
        plan = comm.allgatherv_init(_sds(x), COUNTS)
        plan2 = comm.allgatherv_init(_sds(x), COUNTS)
        assert plan is plan2, "identical *_init must return the cached Plan"
        _, again = jmpi.wait(plan.start(x))
        try:
            plan.start(jnp.zeros((maxc + 1,) + x.shape[1:], x.dtype))
            raise AssertionError("plan.start must reject a mismatched shape")
        except ValueError as e:
            assert "frozen for" in str(e)
        return stacked + again * 0 + shifted.sum() * 0

    got = spmd_collective(f, src)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w)
    stats = jmpi.plan_cache_stats()
    assert stats["hits"] >= 1, stats


def case_vvariant_validation_errors():
    """Counts validation is a clear trace-time error on every surface."""
    src = [rand((2, 2), jnp.float32, seed=i) for i in range(N)]

    def bad_arity(x):
        return jmpi.allgatherv(x, tuple(range(N + 1)))[1]

    try:
        spmd_collective(bad_arity, src)
    except Exception as e:
        assert "counts arity" in str(e), e
    else:
        raise AssertionError("expected counts-arity error")

    def bad_matrix(x):
        n = jmpi.size()
        stack = jnp.zeros((n, 2, 2), x.dtype)
        return jmpi.alltoallv(stack, ((2,) * (n + 1),) * n)[1]

    try:
        spmd_collective(bad_matrix, src)
    except Exception as e:
        assert "counts" in str(e), e
    else:
        raise AssertionError("expected counts-matrix error")


# ---------------------------------------------------------------------- #
# (payload, datatype) uniformity on p2p and collectives
# ---------------------------------------------------------------------- #

def case_p2p_datatype_payloads():
    """send-side vector datatype + recv-side bound subarray: the strided
    column exchange of the paper's Listing-6 story, via explicit
    datatypes rather than manual slicing."""
    if N < 2:
        return
    src = [rand((4, 6), jnp.float64, seed=30 + i) for i in range(N)]

    def f(x):
        # send the left half-columns as a vector datatype over the flat
        # buffer (4 blocks of 3, stride 6 = one per row)
        send_dt = jmpi.vector(4, 3, 6)
        dst = jnp.full((4, 6), -1.0, x.dtype)
        recv_dt = jmpi.subarray((4, 6), (4, 3), (0, 3))
        req = jmpi.isendrecv(x, pairs=[(0, 1)], datatype=send_dt,
                             recv_into=recv_dt.bind(dst))
        status, y = jmpi.wait(req)
        assert status == jmpi.SUCCESS
        return y

    got = spmd_collective(f, src)
    want = np.full((4, 6), -1.0)
    want[:, 3:] = np.asarray(src[0])[:, :3]
    np.testing.assert_allclose(got[1], want, rtol=1e-12)


def case_collective_datatype_payloads():
    """Collectives accept datatype= and bound payloads: allreduce over a
    pytree datatype equals per-leaf oracle sums."""
    trees = [{"a": rand((3, 2), jnp.float32, seed=40 + i),
              "b": rand((5,), jnp.float32, seed=50 + i)} for i in range(N)]
    pd = dt.pytree(trees[0], wire_dtype=jnp.float32)
    a_want = ref.allreduce([np.asarray(t["a"], np.float64)
                            for t in trees], "sum")
    b_want = ref.allreduce([np.asarray(t["b"], np.float64)
                            for t in trees], "sum")

    # pack the tree leaves as the payload via spmd_collective's array-only
    # plumbing: stack (a.flat, b.flat) into one vector per rank
    vecs = [pd.pack(t) for t in trees]

    def f(v):
        # bound payload (datatype already applied host-side); reduce and
        # unpack through the datatype
        _, red = jmpi.allreduce(v)
        tree = pd.unpack(red)
        return jnp.concatenate([tree["a"].reshape(-1), tree["b"].reshape(-1)])

    got = spmd_collective(f, vecs)
    want = np.concatenate([a_want[0].reshape(-1), b_want[0].reshape(-1)])
    for g in got:
        np.testing.assert_allclose(g, want, rtol=5e-5, atol=1e-5)


# ---------------------------------------------------------------------- #
# ERR_TRUNCATE satellite: strided/ragged recv_into across all three paths
# ---------------------------------------------------------------------- #

def case_err_truncate_three_paths():
    """A receive layout statically smaller than the message reports
    ERR_TRUNCATE (leading elements land) on the blocking path, the
    irecv+wait path, AND the persistent-plan path; an exactly-sized
    strided layout reports SUCCESS."""
    if N < 2:
        return
    src = [rand((4, 4), jnp.float32, seed=60 + i) for i in range(N)]

    def flag(status):
        return 1000.0 * (status == jmpi.ERR_TRUNCATE)

    def blocking(x):
        dst = jnp.full((6, 6), -1.0, x.dtype)
        small = jmpi.View(dst, (slice(0, 2), slice(0, 6, 2)))  # 6 < 16
        status, y = jmpi.sendrecv(x, pairs=[(0, 1)], recv_into=small)
        return y + flag(status)

    got = spmd_collective(blocking, src)
    want = np.full((6, 6), -1.0)
    want[0:2, 0:6:2] = np.asarray(src[0]).ravel()[:6].reshape(2, 3)
    np.testing.assert_allclose(got[1], want + 1000.0, rtol=1e-5)

    def nonblocking(x):
        dst = jnp.zeros((14,), x.dtype)
        ragged = jmpi.indexed([3, 4], [0, 7]).bind(dst)   # 7 < 16
        status, req = jmpi.irecv(x, source=0, dest=1, recv_into=ragged)
        status, y = jmpi.wait(req)
        return y + flag(status)

    got = spmd_collective(nonblocking, src)
    sent = np.asarray(src[0]).ravel()
    want = np.zeros((14,))
    want[0:3] = sent[0:3]
    want[7:11] = sent[3:7]
    np.testing.assert_allclose(got[1], want + 1000.0, rtol=1e-5)

    def persistent(x):
        comm = jmpi.world()
        dst = jnp.full((3, 3), -1.0, x.dtype)
        view = jmpi.View(dst, (slice(0, 3), slice(0, 3)))  # 9 < 16
        plan = comm.sendrecv_init(_sds(x), pairs=[(0, 1)], recv_into=view)
        status, y = jmpi.wait(plan.start(x))
        return y + flag(status)

    got = spmd_collective(persistent, src)
    want = np.asarray(src[0]).ravel()[:9].reshape(3, 3)
    np.testing.assert_allclose(got[1], want + 1000.0, rtol=1e-5)

    def exact_strided(x):
        comm = jmpi.world()
        dst = jnp.full((4, 8), -1.0, x.dtype)
        view = jmpi.View(dst, (slice(0, 4), slice(0, 8, 2)))  # 16 == 16
        plan = comm.sendrecv_init(_sds(x), pairs=[(0, 1)], recv_into=view)
        status, y = jmpi.wait(plan.start(x))
        assert status == jmpi.SUCCESS
        return y

    got = spmd_collective(exact_strided, src)
    want = np.full((4, 8), -1.0)
    want[:, 0:8:2] = np.asarray(src[0])
    np.testing.assert_allclose(got[1], want, rtol=1e-5)


# ---------------------------------------------------------------------- #
# halo faces ride subarray datatypes (downstream rewire pin)
# ---------------------------------------------------------------------- #

def case_face_datatypes_match_manual_slices():
    x = rand((8, 6), jnp.float32, seed=77)
    for axis in (0, 1):
        for side, want in (("lo", np.asarray(x)[:2] if axis == 0
                            else np.asarray(x)[:, :2]),
                           ("hi", np.asarray(x)[-2:] if axis == 0
                            else np.asarray(x)[:, -2:])):
            f = dt.face(x.shape, axis, side, 2)
            np.testing.assert_array_equal(np.asarray(f.pack(x)), want,
                                          err_msg=f"face {axis} {side}")
