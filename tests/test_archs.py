"""Per-architecture smoke + consistency tests (reduced configs, 1 CPU device).

For each of the 10 assigned architectures:
  * one train step: finite loss, gradient flows (no NaNs),
  * prefill + decode: logits match the full-sequence forward pass
    (absorbed-MLA decode vs expanded train path, SWA ring cache vs masked
    prefill, SSD chunked scan vs single-step recurrence, etc.).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_tiny
from repro.launch.specs import synth_batch
from repro.models import lm as lm_lib
from repro.models.layers import rmsnorm
from repro.models.lm import embed_inputs, head_logits, trunk

ARCH_NAMES = list(ARCHS)


def full_logits(cfg, params, batch):
    x, cond = embed_inputs(params, cfg, batch)
    pos = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, _, _ = trunk(params, cfg, x, pos, "train", cond=cond)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return head_logits(params, cfg, x)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_smoke(arch):
    cfg = get_tiny(arch)
    params = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
    batch = synth_batch(cfg, batch=2, seq=16, kind="train")

    def loss_fn(p):
        return lm_lib.train_loss(p, cfg, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat), \
        f"{arch}: NaN/inf gradient"
    # at least one nonzero gradient per top-level group
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_output_shapes(arch):
    cfg = get_tiny(arch)
    params = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
    batch = synth_batch(cfg, batch=2, seq=16, kind="prefill")
    logits, caches = jax.jit(
        lambda p, b: lm_lib.prefill(p, cfg, b, 32))(params, batch)
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_matches_full_forward(arch):
    cfg = get_tiny(arch)
    cfg.dtype = "float32"
    cfg.capacity_factor = 16.0   # remove MoE capacity-drop variance
    params = lm_lib.init_params(cfg, jax.random.PRNGKey(1))
    S, MAX = 10, 24
    fullb = synth_batch(cfg, batch=2, seq=S + 3, kind="prefill", seed=5)

    def slice_b(b, sl, decode=False):
        out = {}
        for k, v in b.items():
            if k == "tokens" and cfg.n_img_tokens:
                out[k] = v[:, max(0, sl.start - cfg.n_img_tokens)
                           if sl.start else 0: sl.stop - cfg.n_img_tokens]
            elif k in ("tokens", "embeds"):
                out[k] = v[:, sl]
            elif k == "image_embeds" and decode:
                continue
            else:
                out[k] = v
        return out

    ref = np.asarray(full_logits(cfg, params, fullb))
    logits, caches = lm_lib.prefill(params, cfg, slice_b(fullb, slice(0, S)),
                                    MAX)
    np.testing.assert_allclose(np.asarray(logits)[:, 0], ref[:, S - 1],
                               atol=2e-5, rtol=1e-4)
    for t in range(S, S + 3):
        db = slice_b(fullb, slice(t, t + 1), decode=True)
        logits, caches = lm_lib.decode_step(params, cfg, db, caches, t)
        np.testing.assert_allclose(np.asarray(logits)[:, 0], ref[:, t],
                                   atol=2e-5, rtol=1e-4,
                                   err_msg=f"{arch} decode step t={t}")


def test_swa_ring_cache_wraps():
    """Decode far past the window: ring cache must keep exactly the last
    `window` positions (h2o-danube family behaviour)."""
    cfg = get_tiny("h2o-danube-3-4b")
    cfg.dtype = "float32"
    cfg.window = 8
    params = lm_lib.init_params(cfg, jax.random.PRNGKey(2))
    total = 24
    fullb = synth_batch(cfg, batch=1, seq=total, kind="prefill", seed=3)
    ref = np.asarray(full_logits(cfg, params, fullb))
    S = 4
    logits, caches = lm_lib.prefill(
        params, cfg, {"tokens": fullb["tokens"][:, :S]}, max_len=cfg.window)
    for t in range(S, total):
        db = {"tokens": fullb["tokens"][:, t:t + 1]}
        logits, caches = lm_lib.decode_step(params, cfg, db, caches, t)
        np.testing.assert_allclose(np.asarray(logits)[:, 0], ref[:, t],
                                   atol=2e-5, rtol=1e-4,
                                   err_msg=f"ring decode t={t}")


def test_moe_router_load_balance_loss_positive():
    cfg = get_tiny("mixtral-8x22b")
    params = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
    batch = synth_batch(cfg, batch=2, seq=16, kind="train")
    _, metrics = jax.jit(lambda p, b: lm_lib.train_loss(p, cfg, b))(params, batch)
    assert float(metrics["aux_loss"]) > 0


def test_deepseek_mtp_loss_present():
    cfg = get_tiny("deepseek-v3-671b")
    params = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
    batch = synth_batch(cfg, batch=2, seq=16, kind="train")
    _, metrics = jax.jit(lambda p, b: lm_lib.train_loss(p, cfg, b))(params, batch)
    assert "mtp_ce" in metrics and np.isfinite(float(metrics["mtp_ce"]))


def test_param_counts_full_configs():
    """Analytic parameter counts of the FULL assigned configs land near the
    published sizes (eval_shape only — no allocation)."""
    from repro.configs import get_config
    expected = {  # (low, high) bounds in billions
        "qwen2-1.5b": (1.2, 1.9), "yi-6b": (5.5, 6.5),
        "minitron-8b": (7.0, 10.0), "h2o-danube-3-4b": (3.3, 4.4),
        "mixtral-8x22b": (120, 150), "deepseek-v3-671b": (600, 700),
        "xlstm-350m": (0.25, 0.45), "zamba2-1.2b": (0.9, 1.6),
        # internvl2 band excludes the stubbed 300M InternViT frontend
        "musicgen-large": (2.8, 3.7), "internvl2-1b": (0.4, 1.1),
    }
    from repro.models.lm import count_params
    for arch, (lo, hi) in expected.items():
        n = count_params(get_config(arch)) / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo},{hi}]B"
