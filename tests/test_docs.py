"""Documentation invariants (host-side, no devices).

1. Link checker: every relative markdown link in README.md and docs/*.md
   resolves to an existing file, and every `#anchor` (same-file or
   cross-file) matches a real heading (GitHub slugification).
2. Docstring guard: every name exported by ``repro.core.__all__`` is
   documented, and every public callable of the ``repro.core`` modules the
   docstring sweep covers (comm, registry, plans, topology, operators,
   views) has a docstring.

Run by the CI ``docs`` job and by the tier-1 suite.
"""

from __future__ import annotations

import inspect
import os
import re

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_LINK = re.compile(r"(?<!!)\[[^\]]+\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_CODE_FENCE = re.compile(r"```.*?```", re.DOTALL)


def _doc_files():
    files = [os.path.join(REPO, "README.md")]
    docs = os.path.join(REPO, "docs")
    for name in sorted(os.listdir(docs)):
        if name.endswith(".md"):
            files.append(os.path.join(docs, name))
    return files


def _slugify(heading: str) -> str:
    """GitHub anchor slug: lowercase, drop punctuation, spaces → hyphens."""
    text = re.sub(r"[`*_]", "", heading.strip()).lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def _anchors(path: str) -> set[str]:
    with open(path) as f:
        text = _CODE_FENCE.sub("", f.read())
    return {_slugify(h) for h in _HEADING.findall(text)}


def test_markdown_links_resolve():
    problems = []
    for path in _doc_files():
        with open(path) as f:
            text = _CODE_FENCE.sub("", f.read())
        for target in _LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            file_part, _, anchor = target.partition("#")
            if file_part:
                dest = os.path.normpath(
                    os.path.join(os.path.dirname(path), file_part))
                if not os.path.exists(dest):
                    problems.append(f"{path}: broken link -> {target}")
                    continue
            else:
                dest = path
            if anchor and dest.endswith(".md"):
                if anchor not in _anchors(dest):
                    problems.append(
                        f"{path}: missing anchor #{anchor} in {dest}")
    assert not problems, "\n".join(problems)


def test_every_core_export_is_documented():
    import repro.core as jmpi

    undocumented = []
    for name in jmpi.__all__:
        obj = getattr(jmpi, name)
        if not (callable(obj) or inspect.isclass(obj)
                or inspect.ismodule(obj)):
            continue  # plain data constants (SUCCESS, ANY_TAG, ...)
        if not (getattr(obj, "__doc__", None) or "").strip():
            undocumented.append(name)
    assert not undocumented, (
        f"repro.core exports without a docstring: {undocumented}")


def test_swept_modules_public_callables_have_docstrings():
    """The ISSUE-3 docstring sweep: every public callable defined in the
    swept repro.core modules carries a docstring (methods included).
    ISSUE-5 adds the datatype layer (datatypes, vcollectives) to the
    sweep."""
    from repro.core import (comm, datatypes, operators, plans, registry,
                            topology, vcollectives, views)

    problems = []
    for mod in (comm, registry, plans, topology, operators, views,
                datatypes, vcollectives):
        for name, obj in vars(mod).items():
            if name.startswith("_") or not callable(obj):
                continue
            if getattr(obj, "__module__", None) != mod.__name__:
                continue  # re-exports
            if not (obj.__doc__ or "").strip():
                problems.append(f"{mod.__name__}.{name}")
            if inspect.isclass(obj):
                for mname, meth in vars(obj).items():
                    if mname.startswith("_") or not callable(meth):
                        continue
                    if not (getattr(meth, "__doc__", None) or "").strip():
                        problems.append(f"{mod.__name__}.{name}.{mname}")
    assert not problems, (
        f"public callables without docstrings: {problems}")
