"""Persistent-channel lifecycle cases (multiproc backend ONLY — these
exercise the zero-copy channel fast path behind ``*_init`` plans, so they
are launched exclusively through ``assert_case_multiproc`` by
``tests/test_channels_multiproc.py``; there is no emulated twin).

Covered per (transport, nprocs) job: plan execution through negotiated
channels (ring sendrecv, repeated), channel reuse across epoch bumps (the
case-runner's own bump+barrier discipline), every channel-lowered
collective against a local numpy oracle, static ERR_TRUNCATE surfacing at
plan-init/negotiation time, and the wire-spy proof that steady state
moves ZERO meta bytes and zero eager frames.
"""

from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np

import repro.core as jmpi
from repro.core import p2p, plans
from repro.core.operators import Operator

N = int(os.environ.get("JMPI_NP", "2"))


def _comm():
    comm = jmpi.world()
    assert comm.endpoint is not None, "cases_channels requires multiproc"
    return comm


def _ring_perm():
    return [(r, (r + 1) % N) for r in range(N)]


def case_persistent_sendrecv_ring():
    """A ring sendrecv plan binds the channel lowering and executes
    repeatedly: after k hops every rank holds the payload that originated
    k ranks behind it."""
    comm = _comm()
    me = comm.rank_id
    plan = plans.sendrecv_init(((8,), jnp.float32), pairs=_ring_perm(),
                               comm=comm)
    assert plan.algorithm == "channel", plan.algorithm
    hops = 5
    x = jnp.arange(8, dtype=jnp.float32) + 100.0 * me
    for _ in range(hops):
        status, x = p2p.wait(plan.start(x))
        assert status == jmpi.SUCCESS
    src = (me - hops) % N
    np.testing.assert_array_equal(
        np.asarray(x), np.arange(8, dtype=np.float32) + 100.0 * src)


def case_channel_reuse_across_epochs():
    """One plan, three program epochs: the negotiated channels survive
    ``bump_epoch`` (shm republishes its generation word in place, sock
    re-packs its cached header) and carry the next epoch's messages."""
    comm = _comm()
    ep, me = comm.endpoint, comm.rank_id
    plan = plans.sendrecv_init(((4,), jnp.float32), pairs=_ring_perm(),
                               comm=comm)
    before = len(ep._channels)
    for round_ in range(3):
        x = jnp.full((4,), float(10 * round_ + me), jnp.float32)
        _, y = p2p.wait(plan.start(x))
        np.testing.assert_array_equal(
            np.asarray(y), np.full(4, 10.0 * round_ + (me - 1) % N))
        ep.bump_epoch()   # collective: every rank bumps, then aligns
        ep.barrier()
    assert len(ep._channels) == before, \
        "epoch bumps must reuse channels, not renegotiate"


def case_persistent_collectives_match_numpy():
    """Every channel-lowered collective plan (allreduce, bcast, allgather,
    reduce_scatter, alltoall) against a locally computed numpy oracle."""
    comm = _comm()
    me = comm.rank_id
    ranks = np.arange(N, dtype=np.float32)

    x = jnp.arange(6, dtype=jnp.float32) + me
    p = plans.allreduce_init(x, comm=comm)
    _, out = p2p.wait(p.start(x))
    want = N * np.arange(6, dtype=np.float32) + ranks.sum()
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6)

    p = plans.allreduce_init(x, Operator.MAX, comm=comm)
    _, out = p2p.wait(p.start(x))
    np.testing.assert_allclose(np.asarray(out),
                               np.arange(6, dtype=np.float32) + (N - 1))

    root = N - 1
    p = plans.bcast_init(((5,), jnp.float32), root=root, comm=comm)
    xb = jnp.arange(5, dtype=jnp.float32) * (me + 1)
    _, out = p2p.wait(p.start(xb))
    np.testing.assert_array_equal(np.asarray(out),
                                  np.arange(5, dtype=np.float32) * (root + 1))

    p = plans.allgather_init(((3,), jnp.float32), comm=comm)
    xg = jnp.full((3,), float(me), jnp.float32)
    _, out = p2p.wait(p.start(xg))
    np.testing.assert_array_equal(np.asarray(out), np.repeat(ranks, 3))

    p = plans.reduce_scatter_init(((2 * N,), jnp.float32), comm=comm)
    xr = jnp.arange(2 * N, dtype=jnp.float32) + me
    _, out = p2p.wait(p.start(xr))
    want = N * np.arange(2 * N, dtype=np.float32)[2 * me:2 * me + 2] \
        + ranks.sum()
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6)

    p = plans.alltoall_init(((2 * N, 3), jnp.float32), comm=comm)
    xa = jnp.asarray(
        np.arange(2 * N * 3, dtype=np.float32).reshape(2 * N, 3) + 100 * me)
    _, out = p2p.wait(p.start(xa))
    base_block = np.arange(2 * N * 3, dtype=np.float32).reshape(2 * N, 3)
    # rank r receives slot r of every sender s
    want = np.concatenate(
        [base_block[2 * me:2 * me + 2] + 100 * s for s in range(N)], axis=0)
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6)


def case_err_truncate_at_init():
    """A ``recv_into`` layout statically smaller than the frozen message
    carries ERR_TRUNCATE on every Request the plan starts — the status is
    computed at init (the same moment the channels are negotiated), and
    the truncated leading elements still land through the channel."""
    comm = _comm()
    me = comm.rank_id
    x = jnp.arange(16, dtype=jnp.float32).reshape(4, 4) + 100.0 * me
    dst = jnp.full((3, 3), -1.0, jnp.float32)
    view = jmpi.View(dst, (slice(0, 3), slice(0, 3)))   # 9 < 16
    plan = plans.sendrecv_init(((4, 4), jnp.float32), pairs=_ring_perm(),
                               comm=comm, recv_into=view)
    assert plan.algorithm == "channel", plan.algorithm
    assert plan.status == jmpi.ERR_TRUNCATE, \
        "truncation must be known statically at plan init"
    status, y = p2p.wait(plan.start(x))
    assert status == jmpi.ERR_TRUNCATE
    src = (me - 1) % N
    want = (np.arange(16, dtype=np.float32) + 100.0 * src)[:9].reshape(3, 3)
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-6)


def case_zero_meta_steady_state():
    """The wire spy proves the fast path: after warmup, three plan starts
    move ZERO meta bytes and ZERO eager frames — only channel payload."""
    comm = _comm()
    ep = comm.endpoint
    plan = plans.sendrecv_init(((32,), jnp.float32), pairs=_ring_perm(),
                               comm=comm)
    x = jnp.ones((32,), jnp.float32)
    _, x = p2p.wait(plan.start(x))        # warm: negotiation already done
    ep.reset_wire_stats()
    for _ in range(3):
        _, x = p2p.wait(plan.start(x))
    stats = ep.wire_stats()
    assert stats["meta_bytes"] == 0, stats
    assert stats["frames"] == 0, stats
    assert stats["chan_msgs"] == 3, stats
    assert stats["chan_bytes"] >= 3 * 32 * 4, stats
