"""Pytest wrappers for the multi-rank jmpi cases (8 emulated devices).

The device-count flag is process-global, so each case module runs in a child
process (see repro.testing); the whole module executes ONCE per device count
(cached transcript) and each parametrized test asserts its own case — per-
case reporting at one subprocess per module.
"""

import pytest

from repro.testing import assert_case

pytestmark = pytest.mark.multidev

CASES = [
    "case_rank_size_initialized",
    "case_wtime",
    "case_sendrecv_ring_all_dtypes",
    "case_listing5_exchange",
    "case_send_recv_blocking_pair",
    "case_isend_wait_test_variants",
    "case_p2p_trace_time_topology_errors",
    "case_p2p_tag_matching",
    "case_p2p_err_truncate",
    "case_waitany_testany_ordering",
    "case_allreduce_operators",
    "case_allreduce_logical",
    "case_bcast_all_dtypes",
    "case_scatter_gather_allgather",
    "case_alltoall_reduce_scatter",
    "case_barrier_and_token_sequencing",
    "case_view_strided_send_recv",
    "case_view_transposed_fortran_analogue",
    "case_subcommunicators_2d",
    "case_multiaxis_world_ppermute",
    "case_ring_allreduce_matches_psum",
    "case_ring_allgather_matches",
    "case_compressed_allreduce_accuracy_and_feedback",
    "case_disable_jit_debug_mode",
    "case_property_collectives_match_oracle",
    "case_property_permute_roundtrip",
]

# Individual reruns in a fresh child:
#   PYTHONPATH=src python -c "from repro.testing import run_cases; \
#       run_cases('tests.cases_core', 8, only='case_name')"


@pytest.mark.parametrize("case", CASES)
def test_core_case(case):
    assert_case("tests.cases_core", case, n_devices=8)
