"""Pytest wrappers for the multi-rank jmpi cases (8 emulated devices).

The device-count flag is process-global, so each case module runs in a child
process (see repro.testing); the transcript lists per-case PASS/FAIL.
"""

import pytest

from repro.testing import run_cases

CASES = [
    "case_rank_size_initialized",
    "case_wtime",
    "case_sendrecv_ring_all_dtypes",
    "case_listing5_exchange",
    "case_send_recv_blocking_pair",
    "case_isend_wait_test_variants",
    "case_p2p_trace_time_topology_errors",
    "case_allreduce_operators",
    "case_allreduce_logical",
    "case_bcast_all_dtypes",
    "case_scatter_gather_allgather",
    "case_alltoall_reduce_scatter",
    "case_barrier_and_token_sequencing",
    "case_view_strided_send_recv",
    "case_view_transposed_fortran_analogue",
    "case_subcommunicators_2d",
    "case_multiaxis_world_ppermute",
    "case_ring_allreduce_matches_psum",
    "case_ring_allgather_matches",
    "case_compressed_allreduce_accuracy_and_feedback",
    "case_disable_jit_debug_mode",
    "case_property_collectives_match_oracle",
    "case_property_permute_roundtrip",
]

# One subprocess for the whole module keeps jax-import cost paid once; the
# transcript still reports each case. Individual reruns:
#   pytest -k case_name  (runs just that case in its own child)


@pytest.mark.parametrize("case", CASES)
def test_core_case(case):
    run_cases("tests.cases_core", n_devices=8, only=case)
