"""Multi-rank PDE cases (paper §3): halo exchange correctness, distributed
Cahn–Hilliard vs single-device oracle, MPDATA vs oracle across decomposition
layouts + conservation/positivity properties.  Run under 8 emulated devices.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

import repro.core as jmpi
from repro.core import compat
from repro.pde import cahn_hilliard as ch
from repro.pde import mpdata
from repro.pde.stencil import halo_exchange_2d


def mesh2d(rows, cols, axes=("px", "py")):
    return compat.make_mesh((rows, cols), axes)


def case_halo_exchange_matches_roll():
    """Halo-padded blocks must reproduce the globally-rolled array."""
    for rows, cols in ((2, 4), (4, 2), (1, 8), (8, 1)):
        mesh = mesh2d(rows, cols)
        n = 16
        x = jnp.arange(n * n, dtype=jnp.float32).reshape(n, n)

        @jmpi.spmd(mesh, in_specs=P("px", "py"), out_specs=P("px", "py"))
        def f(blk):
            world = jmpi.world()
            cart = world.cart_create((rows, cols), periods=(True, True))
            h = halo_exchange_2d(blk, cart, halo=1)
            # interior of padded block must equal block; check neighbours by
            # reconstructing the shifted field
            up = h[0:blk.shape[0], 1:1 + blk.shape[1]]
            return up  # block shifted down by one row (periodic)

        got = f(x)
        want = jnp.roll(x, 1, axis=0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   err_msg=f"decomp {rows}x{cols}")


def case_cahn_hilliard_matches_oracle():
    rng = np.random.default_rng(0)
    n = 32
    c0 = jnp.asarray(0.5 + 0.01 * rng.standard_normal((n, n)), jnp.float32)
    for rows, cols in ((2, 4), (1, 8)):
        mesh = mesh2d(rows, cols)
        run = ch.make_solver(mesh, (rows, cols), inner_steps=20)
        got = run(c0, n_outer=1)
        want = c0
        for _ in range(20):
            want = ch.reference_step(want)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-4,
                                   err_msg=f"decomp {rows}x{cols}")


def case_mpdata_matches_oracle_all_layouts():
    """Paper Fig. 3: decomposition along dim 0 / dim 1 / 2-D must all give
    the same (oracle) answer."""
    rng = np.random.default_rng(1)
    n = 32
    psi0 = jnp.asarray(np.exp(-((np.arange(n) - 16) ** 2)[:, None] / 32
                              - ((np.arange(n) - 12) ** 2)[None, :] / 32),
                       jnp.float32) + 0.01
    want = psi0
    for _ in range(10):
        want = mpdata.reference_step(want)
    for rows, cols in ((8, 1), (1, 8), (2, 4)):
        mesh = mesh2d(rows, cols)
        run = mpdata.make_solver(mesh, inner_steps=10)
        got = run(psi0, n_outer=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-4,
                                   err_msg=f"decomp {rows}x{cols}")


def case_mpdata_conservation_and_positivity():
    """Property: homogeneous periodic advection conserves Σψ and keeps ψ>0."""
    rng = np.random.default_rng(2)
    n = 32
    psi0 = jnp.asarray(np.abs(rng.standard_normal((n, n))) + 0.1, jnp.float32)
    mesh = mesh2d(2, 4)
    run = mpdata.make_solver(mesh, inner_steps=25)
    out = run(psi0, n_outer=2)
    np.testing.assert_allclose(float(out.sum()), float(psi0.sum()),
                               rtol=1e-5)
    assert float(out.min()) >= 0.0


def case_cahn_hilliard_diagnostics_mass():
    """diagnostics=True: the in-program global_sum (a scalar jmpi allreduce,
    policy-routed to the small-payload algorithm) reports the exact global
    mass of the final field."""
    rng = np.random.default_rng(4)
    n = 32
    c0 = jnp.asarray(0.5 + 0.05 * rng.standard_normal((n, n)), jnp.float32)
    mesh = mesh2d(2, 4)
    run = ch.make_solver(mesh, (2, 4), k=0.0, inner_steps=10,
                         diagnostics=True)
    out, mass = run(c0, n_outer=1)
    np.testing.assert_allclose(float(mass), float(jnp.sum(out)), rtol=1e-5)
    np.testing.assert_allclose(float(mass), float(jnp.sum(c0)), rtol=1e-5)


def case_cahn_hilliard_conserves_mass_when_k0():
    """Property: pure Cahn–Hilliard (k=0) conserves total concentration."""
    rng = np.random.default_rng(3)
    n = 32
    c0 = jnp.asarray(0.5 + 0.05 * rng.standard_normal((n, n)), jnp.float32)
    mesh = mesh2d(2, 4)
    run = ch.make_solver(mesh, (2, 4), k=0.0, inner_steps=50)
    out = run(c0, n_outer=1)
    np.testing.assert_allclose(float(out.mean()), float(c0.mean()),
                               rtol=1e-6)
