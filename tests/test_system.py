"""End-to-end behaviour tests: the trainer learns, the engine serves, the
dry-run machinery lowers/compiles, and the HLO cost model is calibrated."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_tiny
from repro.configs.base import RunConfig, ShapeCell
from repro.launch.mesh import make_host_mesh
from repro.models import lm as lm_lib
from repro.serve.engine import Engine, ServeConfig
from repro.train import optim
from repro.train.data import SyntheticLM
from repro.train.trainer import build_train_step


def test_trainer_learns_synthetic_bigrams():
    """30 steps on the synthetic bigram stream must cut the loss clearly
    below ln(vocab) (the data is ~86% deterministic next-token)."""
    cfg = get_tiny("yi-6b")
    cfg.dtype = "float32"
    mesh = make_host_mesh(1, axes=("data",))
    cell = ShapeCell("t", 64, 8, "train")
    rc = RunConfig(learning_rate=3e-3)
    step = build_train_step(cfg, rc, mesh, cell).jitted()
    params = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
    opt = optim.init(params, rc)
    data = SyntheticLM(cfg, 8, 64)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::6]


def test_engine_generates_batched():
    cfg = get_tiny("h2o-danube-3-4b")   # exercises the SWA ring cache
    params = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(max_prompt=16, max_new_tokens=8))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (4, 12), dtype=np.int32)
    out = eng.generate(prompts)
    assert out.shape == (4, 8)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()


def test_microbatched_step_matches_plain():
    """Gradient accumulation (k=4) must match the single-shot step."""
    cfg = get_tiny("qwen2-1.5b")
    cfg.dtype = "float32"
    mesh = make_host_mesh(1, axes=("data",))
    cell = ShapeCell("t", 32, 8, "train")
    params = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, 8, 32)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}

    outs = []
    for k in (0, 4):
        rc = RunConfig(learning_rate=1e-3, microbatch=k)
        step = build_train_step(cfg, rc, mesh, cell).jitted()
        p0 = jax.tree.map(jnp.copy, params)   # step donates its inputs
        opt = optim.init(p0, rc)
        p, o, m = step(p0, opt, batch)
        outs.append((p, float(m["loss"])))
    for a, b in zip(jax.tree.leaves(outs[0][0]), jax.tree.leaves(outs[1][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                                   rtol=2e-4)


def test_dryrun_machinery_tiny():
    """lower+compile+analyze a tiny cell on the host mesh (the same path
    the 512-device dry-run runs; device count is the only difference)."""
    from repro.launch import hlo_cost
    from repro.launch.roofline import model_flops_for, roofline_terms

    cfg = get_tiny("yi-6b")
    mesh = make_host_mesh(1, axes=("data",))
    cell = ShapeCell("t", 64, 4, "train")
    bundle = build_train_step(cfg, RunConfig(microbatch=2), mesh, cell)
    compiled = bundle.lower().compile()
    cost = hlo_cost.analyze(compiled.as_text())
    assert cost["flops"] > 0 and cost["bytes"] > 0
    terms = roofline_terms(
        cost, {"total_bytes": cost["collective_bytes"]}, 1,
        model_flops=model_flops_for(cfg, cell))
    assert terms["dominant"] in ("compute", "memory", "collective")
    # analyzer flops within 3x of 6ND (remat + attention overhead band)
    assert 0.5 < cost["flops"] / terms["model_flops"] < 3.0


def test_hlo_cost_trip_counts():
    """The analyzer multiplies while-loop bodies by their trip counts."""
    from repro.launch import hlo_cost

    def g(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((17, 128, 128), jnp.float32)
    hlo = jax.jit(g).lower(x, ws).compile().as_text()
    r = hlo_cost.analyze(hlo)
    expected = 17 * 2 * 64 * 128 * 128
    assert 0.95 < r["flops"] / expected < 1.1
