"""Collective-algorithm registry cases — device-count agnostic.

Run under 1, 2 and 8 emulated devices (see tests/test_registry_multidev.py):
every registered algorithm of every logical op must match the numpy oracle
— and, for allreduce, the default ``jmpi.allreduce`` dispatch — across
Operator variants, dtypes (float32 / bfloat16 / int32) and non-contiguous
``View`` payloads.  Property-based via repro.testing.property_testing
(hypothesis when installed, deterministic shim otherwise).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

import repro.core as jmpi
from repro.core import compat, ref, registry
from repro.testing import property_testing

import os

# Multiproc jobs size the world by real process count (JMPI_NP); emulated
# runs use the device count chosen by the harness.
_BACKEND = os.environ.get("JMPI_BACKEND", "emulated")
N = (int(os.environ["JMPI_NP"]) if _BACKEND == "multiproc"
     else len(jax.devices()))

DTYPES = [jnp.float32, jnp.bfloat16, jnp.int32]
OP_NAMES = {jmpi.Operator.SUM: "sum", jmpi.Operator.PROD: "prod",
            jmpi.Operator.MIN: "min", jmpi.Operator.MAX: "max",
            jmpi.Operator.LAND: "land", jmpi.Operator.LOR: "lor"}


def mesh1d():
    return compat.make_mesh((N,), ("ranks",))


def spmd_collective(fn, shards):
    if _BACKEND == "multiproc":
        from repro.transport.testing import run_collective
        return run_collective(fn, shards)
    mesh = mesh1d()

    @jmpi.spmd(mesh, in_specs=P("ranks"), out_specs=P("ranks"))
    def run(x):
        return fn(x[0])[None]

    out = run(jnp.stack(shards))
    return [np.asarray(out[i]) for i in range(N)]


def rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    if jnp.issubdtype(jnp.dtype(dtype), np.integer):
        x = rng.integers(-9, 9, size=shape)
    else:
        x = rng.standard_normal(shape)
    return jnp.asarray(x, dtype=dtype)


# topk_ef keeps only the top-k magnitudes per call and banks the rest as
# error-feedback residual — its stateless output is intentionally NOT a
# pointwise approximation of the dense reduce, so the exhaustive sweeps
# skip it; its contract is oracle-pinned by the EF telescoping identity
# in tests/cases_compression.py.
SPARSIFYING = ("topk_ef",)


def _tol(dtype, algo, op):
    # int8_ef quantizes to 8 bits against the per-rank amax — same loss
    # class as the bf16 wire format (error << 0.1·N for randn payloads).
    if dtype == jnp.bfloat16 or algo in ("bf16_wire", "int8_ef"):
        return dict(rtol=0.1, atol=0.1 * max(1, N))
    if dtype == jnp.int32:
        return dict(rtol=0, atol=0)
    return dict(rtol=5e-5, atol=1e-5)


def _oracle_cmp(got, want, **tol):
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g, np.float64),
                                   np.asarray(w, np.float64), **tol)


# ---------------------------------------------------------------------- #
# exhaustive: every algorithm × op × dtype vs oracle AND default dispatch
# ---------------------------------------------------------------------- #

def case_allreduce_all_algorithms_match_oracle():
    for op, name in OP_NAMES.items():
        for dt in DTYPES:
            if name in ("land", "lor") and dt == jnp.bfloat16:
                continue  # logical ops over float payloads: int path only
            src = [rand((3, 2), dt, seed=11 * i + 1) for i in range(N)]
            np_src = [np.asarray(s, np.float64) if dt != jnp.int32
                      else np.asarray(s) for s in src]
            want = ref.allreduce(np_src, name)
            deflt = spmd_collective(
                lambda x, o=op: jmpi.allreduce(x, o)[1], src)
            for algo in registry.algorithms("allreduce"):
                if algo in SPARSIFYING:
                    continue
                try:
                    got = spmd_collective(
                        lambda x, a=algo, o=op: jmpi.allreduce(
                            x, o, algorithm=a)[1], src)
                except ValueError:
                    # algorithm statically unsupported (e.g. ring×MIN,
                    # bf16_wire×int, rd×non-pow2 group): selection contract
                    continue
                _oracle_cmp(got, want, **_tol(dt, algo, name),
                            err_msg=f"{algo} {name} {dt}")
                _oracle_cmp(got, deflt, **_tol(dt, algo, name),
                            err_msg=f"{algo} vs default {name} {dt}")


def case_bcast_allgather_rs_alltoall_algorithms_match_oracle():
    for dt in DTYPES:
        src = [rand((N * 2, 3), dt, seed=7 * i + 3) for i in range(N)]
        np_src = [np.asarray(s, np.float64) if dt != jnp.int32
                  else np.asarray(s) for s in src]
        for algo in registry.algorithms("bcast"):
            got = spmd_collective(
                lambda x, a=algo: jmpi.bcast(x, root=N - 1, algorithm=a)[1],
                src)
            # bcast moves bits verbatim: exact for every dtype/algorithm
            _oracle_cmp(got, ref.bcast(np_src, root=N - 1), rtol=0, atol=0,
                        err_msg=f"bcast {algo} {dt}")
        for algo in registry.algorithms("allgather"):
            got = spmd_collective(
                lambda x, a=algo: jmpi.allgather(x, algorithm=a)[1], src)
            _oracle_cmp(got, ref.allgather(np_src), rtol=0, atol=0,
                        err_msg=f"allgather {algo} {dt}")
        for algo in registry.algorithms("reduce_scatter"):
            if algo in SPARSIFYING:
                continue
            try:
                got = spmd_collective(
                    lambda x, a=algo: jmpi.reduce_scatter(
                        x, algorithm=a)[1], src)
            except ValueError:
                continue
            _oracle_cmp(got, ref.reduce_scatter(np_src),
                        **_tol(dt, algo, "sum"),
                        err_msg=f"reduce_scatter {algo} {dt}")
        for algo in registry.algorithms("alltoall"):
            got = spmd_collective(
                lambda x, a=algo: jmpi.alltoall(x, algorithm=a)[1], src)
            _oracle_cmp(got, ref.alltoall(np_src), rtol=0, atol=0,
                        err_msg=f"alltoall {algo} {dt}")


def case_view_payloads_all_allreduce_algorithms():
    """Non-contiguous (strided) View payloads through every algorithm."""
    for algo in registry.algorithms("allreduce"):
        if algo in SPARSIFYING:
            continue
        src = [rand((6, 6), jnp.float32, seed=13 * i + 5) for i in range(N)]

        def f(x, a=algo):
            view = jmpi.View(x, (slice(1, 5), slice(0, 6, 2)))
            try:
                _, y = jmpi.allreduce(view, algorithm=a)
            except ValueError:
                _, y = jmpi.allreduce(view)
            return y

        got = spmd_collective(f, src)
        want = ref.allreduce(
            [np.asarray(s, np.float64)[1:5, 0:6:2] for s in src], "sum")
        _oracle_cmp(got, want, **_tol(jnp.float32, algo, "sum"),
                    err_msg=f"view allreduce {algo}")


# ---------------------------------------------------------------------- #
# property-based sweep (hypothesis or shim)
# ---------------------------------------------------------------------- #

def case_property_all_algorithms_match_default():
    given, settings, st = property_testing()

    algos = [a for a in registry.algorithms("allreduce")
             if a not in SPARSIFYING]
    ops = [jmpi.Operator.SUM, jmpi.Operator.MIN, jmpi.Operator.MAX]

    @settings(max_examples=12, deadline=None)
    @given(algo=st.sampled_from(algos), op_i=st.integers(0, len(ops) - 1),
           rows=st.integers(1, 4), cols=st.integers(1, 3),
           dt_i=st.integers(0, len(DTYPES) - 1), seed=st.integers(0, 2 ** 16))
    def inner(algo, op_i, rows, cols, dt_i, seed):
        op, dt = ops[op_i], DTYPES[dt_i]
        src = [rand((rows, cols), dt, seed=seed + i) for i in range(N)]
        try:
            got = spmd_collective(
                lambda x, a=algo, o=op: jmpi.allreduce(x, o, algorithm=a)[1],
                src)
        except ValueError:
            return  # statically unsupported combination
        want = spmd_collective(
            lambda x, o=op: jmpi.allreduce(
                x, o, algorithm="xla_native")[1], src)
        name = OP_NAMES[op]
        _oracle_cmp(got, want, **_tol(dt, algo, name),
                    err_msg=f"{algo} {name} {dt} {rows}x{cols}")

    inner()


# ---------------------------------------------------------------------- #
# selection machinery under devices (policy/override observable in HLO)
# ---------------------------------------------------------------------- #

def case_override_changes_lowering():
    """set_algorithm/algorithm_override actually change the lowered HLO:
    ring allreduce lowers to collective-permute chains, xla_native to one
    all-reduce."""
    if N < 2:
        return  # single rank: every algorithm is the identity
    mesh = mesh1d()

    def lowered(algorithm):
        @jmpi.spmd(mesh, in_specs=P("ranks"), out_specs=P("ranks"))
        def f(x):
            _, y = jmpi.allreduce(x[0], algorithm=algorithm)
            return y[None]

        x = jnp.zeros((N, 64), jnp.float32)
        return jax.jit(f).lower(x).as_text()

    ring_hlo = lowered("ring")
    native_hlo = lowered("xla_native")
    assert ring_hlo.count("collective_permute") >= 2 * (N - 1), \
        "ring allreduce must lower to ppermute chains"
    assert "all-reduce" in native_hlo or "all_reduce" in native_hlo

    with jmpi.algorithm_override(allreduce="ring"):
        via_override = lowered(None)
    assert via_override.count("collective_permute") >= 2 * (N - 1), \
        "algorithm_override must reroute the default dispatch"


def case_policy_table_routes_by_size():
    """A policy with a tiny-payload rule routes small payloads to the rule's
    algorithm and large payloads to the default — observable in the HLO."""
    if N < 2:
        return
    mesh = mesh1d()
    table = jmpi.PolicyTable(
        rules=[jmpi.PolicyRule("allreduce", "ring", max_bytes=1024)],
        default={"allreduce": "xla_native"})
    prev = registry.active_policy()
    jmpi.set_policy(table)
    try:
        def lowered(numel):
            @jmpi.spmd(mesh, in_specs=P("ranks"), out_specs=P("ranks"))
            def f(x):
                _, y = jmpi.allreduce(x[0])
                return y[None]

            x = jnp.zeros((N, numel), jnp.float32)
            return jax.jit(f).lower(x).as_text()

        small = lowered(16)        # 64 B -> ring
        large = lowered(65536)     # 256 KiB -> xla_native
        assert small.count("collective_permute") >= 2 * (N - 1)
        assert large.count("collective_permute") == 0
    finally:
        jmpi.set_policy(prev)
